//! Synthetic token corpora with controllable statistics.
//!
//! Two distributions stand in for the paper's test sets:
//!
//! * **synth-wiki** — a low-entropy second-order Markov language (3
//!   candidate continuations per bigram context, skewed weights), playing
//!   the role of WikiText2.
//! * **synth-c4** — a higher-entropy mixture of two flatter Markov tables
//!   switched per "document", playing the role of C4.
//!
//! The generator is deterministic from a seed; the Rust side is canonical
//! and writes binary token files that the JAX trainer consumes, so both
//! layers see the exact same language. Format: `CLAQTK01 | vocab u32 |
//! n u64 | u16 tokens LE`.

use crate::util::rng::{Rng, SplitMix64};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

pub const VOCAB: usize = 256;
const MAGIC: &[u8; 8] = b"CLAQTK01";

/// Which synthetic language to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// Low-entropy, WikiText2 stand-in.
    SynthWiki,
    /// Higher-entropy mixture, C4 stand-in.
    SynthC4,
}

impl CorpusKind {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::SynthWiki => "synth-wiki",
            CorpusKind::SynthC4 => "synth-c4",
        }
    }

    /// (n candidates, weight skew exponent, mixture tables)
    ///
    /// synth-c4 uses a single table with more, flatter candidates: higher
    /// entropy than synth-wiki but still learnable within the build-time
    /// training budget (a latent mixture proved un-learnable at this model
    /// scale — documented in DESIGN.md §1).
    fn params(&self) -> (usize, f64, usize) {
        match self {
            CorpusKind::SynthWiki => (3, 1.6, 1),
            CorpusKind::SynthC4 => (8, 1.0, 1),
        }
    }

    fn base_seed(&self) -> u64 {
        match self {
            CorpusKind::SynthWiki => 0x51A9_0001,
            CorpusKind::SynthC4 => 0x51A9_0002,
        }
    }
}

/// The second-order Markov language model behind a corpus. Candidate
/// continuations and their weights for a bigram context are derived by
/// hashing, so the full table never needs materializing.
#[derive(Clone, Debug)]
pub struct Language {
    kind: CorpusKind,
    n_candidates: usize,
    weights: Vec<f64>,
}

impl Language {
    pub fn new(kind: CorpusKind) -> Self {
        let (k, skew, _) = kind.params();
        // Zipf-ish weights: w_i ∝ 1/(i+1)^skew
        let mut weights: Vec<f64> = (0..k).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect();
        let z: f64 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= z;
        }
        Self { kind, n_candidates: k, weights }
    }

    /// Candidate next tokens for bigram context (a, b) under `table`.
    ///
    /// The context is deliberately coarsened to (a mod 8, b): 2048 distinct
    /// contexts instead of 65536, so a ~1M-parameter model can actually
    /// memorize the transition structure within the build-time training
    /// budget (the language stays genuinely second-order — the `a` bucket
    /// matters — but is learnable).
    pub fn candidates(&self, a: u16, b: u16, table: usize) -> Vec<u16> {
        let a_bucket = (a % 8) as u64;
        let mut sm = SplitMix64::new(
            self.kind
                .base_seed()
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(a_bucket << 24 | (b as u64) << 4 | table as u64),
        );
        (0..self.n_candidates)
            .map(|_| (sm.next_u64() % VOCAB as u64) as u16)
            .collect()
    }

    /// Sample the next token for context (a, b).
    pub fn sample_next(&self, a: u16, b: u16, table: usize, rng: &mut Rng) -> u16 {
        let cands = self.candidates(a, b, table);
        cands[rng.weighted(&self.weights)]
    }

    /// Probability that `next` follows (a, b) (for entropy checks and the
    /// oracle ranking in task construction). Candidates may repeat; their
    /// weights add.
    pub fn next_prob(&self, a: u16, b: u16, table: usize, next: u16) -> f64 {
        let cands = self.candidates(a, b, table);
        cands
            .iter()
            .zip(&self.weights)
            .filter(|(&c, _)| c == next)
            .map(|(_, &w)| w)
            .sum()
    }

    /// Per-token entropy of the language in nats (the perplexity floor is
    /// exp of this).
    pub fn entropy(&self) -> f64 {
        -self.weights.iter().map(|&w| w * w.ln()).sum::<f64>()
    }

    pub fn n_tables(&self) -> usize {
        self.kind.params().2
    }
}

/// Generate `n` tokens of the given corpus with a deterministic seed.
/// Documents of 256 tokens each; the mixture table is re-drawn per doc.
pub fn generate(kind: CorpusKind, n: usize, seed: u64) -> Vec<u16> {
    let lang = Language::new(kind);
    let mut rng = Rng::with_stream(kind.base_seed() ^ seed, seed);
    let mut out: Vec<u16> = Vec::with_capacity(n);
    let mut table = 0usize;
    let (mut a, mut b) = (0u16, 1u16);
    for i in 0..n {
        if i % 256 == 0 {
            table = rng.below_usize(lang.n_tables());
            // fresh doc opener tokens
            a = rng.below(VOCAB as u64) as u16;
            b = rng.below(VOCAB as u64) as u16;
        }
        let next = lang.sample_next(a, b, table, &mut rng);
        out.push(next);
        a = b;
        b = next;
    }
    out
}

/// Standard splits used by the experiments.
pub struct CorpusSplits {
    pub train: Vec<u16>,
    pub heldout: Vec<u16>,
}

/// Deterministic train/heldout splits per corpus (disjoint seeds).
pub fn splits(kind: CorpusKind, train_n: usize, heldout_n: usize) -> CorpusSplits {
    CorpusSplits {
        train: generate(kind, train_n, 1),
        heldout: generate(kind, heldout_n, 2),
    }
}

/// Write a token file.
pub fn save_tokens(tokens: &[u16], path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(VOCAB as u32).to_le_bytes())?;
    w.write_all(&(tokens.len() as u64).to_le_bytes())?;
    let mut buf = Vec::with_capacity(tokens.len() * 2);
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read a token file.
pub fn load_tokens(path: &Path) -> Result<Vec<u16>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad token-file magic in {}", path.display());
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let vocab = u32::from_le_bytes(b4) as usize;
    if vocab != VOCAB {
        bail!("vocab mismatch: file {vocab}, expected {VOCAB}");
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    let mut buf = vec![0u8; n * 2];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(CorpusKind::SynthWiki, 1000, 7);
        let b = generate(CorpusKind::SynthWiki, 1000, 7);
        assert_eq!(a, b);
        let c = generate(CorpusKind::SynthWiki, 1000, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn kinds_differ() {
        let a = generate(CorpusKind::SynthWiki, 1000, 1);
        let b = generate(CorpusKind::SynthC4, 1000, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_vocab() {
        let toks = generate(CorpusKind::SynthC4, 5000, 3);
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB));
    }

    #[test]
    fn wiki_lower_entropy_than_c4() {
        let w = Language::new(CorpusKind::SynthWiki);
        let c = Language::new(CorpusKind::SynthC4);
        assert!(w.entropy() < c.entropy(), "{} !< {}", w.entropy(), c.entropy());
        // both languages are learnable but nontrivial
        assert!(w.entropy() > 0.3 && c.entropy() < (VOCAB as f64).ln());
    }

    #[test]
    fn empirical_follows_language() {
        // Generated tokens must be high-probability under the language.
        let kind = CorpusKind::SynthWiki;
        let lang = Language::new(kind);
        let toks = generate(kind, 4096, 5);
        let mut hits = 0usize;
        let mut total = 0usize;
        for i in 2..1000 {
            if (i % 256) < 2 {
                continue; // doc boundary resets context
            }
            total += 1;
            if lang.next_prob(toks[i - 2], toks[i - 1], 0, toks[i]) > 0.0 {
                hits += 1;
            }
        }
        // synth-wiki has a single table, so all in-doc transitions must be
        // language-consistent
        assert_eq!(hits, total);
    }

    #[test]
    fn file_round_trip() {
        let toks = generate(CorpusKind::SynthC4, 777, 9);
        let dir = std::env::temp_dir().join("claq_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        save_tokens(&toks, &path).unwrap();
        assert_eq!(load_tokens(&path).unwrap(), toks);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn next_prob_sums_to_one() {
        let lang = Language::new(CorpusKind::SynthC4);
        let total: f64 = (0..VOCAB as u16).map(|t| lang.next_prob(3, 99, 1, t)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
