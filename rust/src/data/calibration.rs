//! Calibration-set sampling (paper Appendix F: "128 random 2048-token
//! segments from the C4 dataset"; scaled to this testbed's sequence
//! length). Segments are drawn at random offsets from a token stream,
//! deterministically from a seed.

use crate::util::rng::Rng;

/// Default calibration configuration mirroring the paper's shape.
#[derive(Clone, Copy, Debug)]
pub struct CalibConfig {
    pub n_segments: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        Self { n_segments: 128, seq_len: 128, seed: 0xCA11B }
    }
}

/// The shared default calibration source of the serving entry points
/// (`claq pack`, `examples/serve_quantized.rs`): the trained C4 corpus
/// from `dir` when present, a synthetic stand-in stream otherwise, sampled
/// with the standard seed. One definition so the CLI artifact and the
/// example artifact cannot silently diverge on the calibration recipe.
pub fn default_calibration(
    dir: &std::path::Path,
    seq_len: usize,
    n_segments: usize,
) -> Vec<Vec<u16>> {
    use crate::data::corpus::{generate, load_tokens, CorpusKind};
    let train = load_tokens(&dir.join("corpus_c4_train.bin"))
        .unwrap_or_else(|_| generate(CorpusKind::SynthC4, 16_384, 3));
    sample_segments(&train, &CalibConfig { n_segments, seq_len, seed: 2 })
}

/// Sample `n_segments` windows of `seq_len` tokens.
pub fn sample_segments(stream: &[u16], cfg: &CalibConfig) -> Vec<Vec<u16>> {
    assert!(
        stream.len() >= cfg.seq_len,
        "stream too short for calibration ({} < {})",
        stream.len(),
        cfg.seq_len
    );
    let mut rng = Rng::new(cfg.seed);
    let max_start = stream.len() - cfg.seq_len;
    (0..cfg.n_segments)
        .map(|_| {
            let start = rng.below_usize(max_start + 1);
            stream[start..start + cfg.seq_len].to_vec()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusKind};

    #[test]
    fn segment_shapes() {
        let stream = generate(CorpusKind::SynthC4, 10_000, 1);
        let cfg = CalibConfig { n_segments: 16, seq_len: 64, seed: 1 };
        let segs = sample_segments(&stream, &cfg);
        assert_eq!(segs.len(), 16);
        assert!(segs.iter().all(|s| s.len() == 64));
    }

    #[test]
    fn deterministic() {
        let stream = generate(CorpusKind::SynthWiki, 5000, 2);
        let cfg = CalibConfig::default();
        assert_eq!(sample_segments(&stream, &cfg), sample_segments(&stream, &cfg));
    }

    #[test]
    fn segments_are_substrings() {
        let stream = generate(CorpusKind::SynthWiki, 4000, 3);
        let cfg = CalibConfig { n_segments: 8, seq_len: 32, seed: 9 };
        for seg in sample_segments(&stream, &cfg) {
            assert!(stream.windows(32).any(|w| w == &seg[..]));
        }
    }

    #[test]
    #[should_panic(expected = "stream too short")]
    fn too_short_stream_panics() {
        sample_segments(&[1, 2, 3], &CalibConfig::default());
    }
}
