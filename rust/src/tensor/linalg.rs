//! Dense linear algebra needed by the GPTQ engine: Cholesky factorization,
//! triangular solves, and symmetric-positive-definite inversion. All in f64
//! internally — the Hessian conditioning at 2-bit targets is poor enough
//! that f32 factorization visibly degrades quantization quality.

use super::Matrix;

/// Lower-triangular Cholesky factor L of a symmetric positive-definite
/// matrix A (so A = L·Lᵀ). Input is row-major n×n in f64. Returns None if
/// the matrix is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L·y = b with L lower triangular (forward substitution).
pub fn solve_lower(l: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    y
}

/// Solve Lᵀ·x = y with L lower triangular (back substitution).
pub fn solve_lower_transpose(l: &[f64], y: &[f64], n: usize) -> Vec<f64> {
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// Invert a symmetric positive-definite matrix via Cholesky.
/// Returns None if not SPD.
pub fn spd_inverse(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky(a, n)?;
    let mut inv = vec![0.0f64; n * n];
    let mut e = vec![0.0f64; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e, n);
        let x = solve_lower_transpose(&l, &y, n);
        for i in 0..n {
            inv[i * n + j] = x[i];
        }
    }
    Some(inv)
}

/// The GPTQ factorization: given SPD H, compute the *upper* Cholesky factor
/// U of H⁻¹ (H⁻¹ = Uᵀ·U is GPTQ's convention where `U = Cholesky(H^-1,
/// upper=True)`; its rows drive the error propagation). Dampening is the
/// caller's responsibility.
pub fn gptq_inverse_factor(h: &[f64], n: usize) -> Option<Vec<f64>> {
    let inv = spd_inverse(h, n)?;
    // Upper Cholesky of inv: inv = Uᵀ·U where U is upper triangular.
    // Compute lower factor of inv and transpose.
    let l = cholesky(&inv, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Some(u)
}

/// The full GPTQ preamble in one call: dampen `h` in place with `damp_pct`
/// and factor it, escalating the dampening ×10 until the factorization
/// succeeds (rank-deficient calibration sets at small sample counts).
/// Panics once the cumulative dampening exceeds 1e6 — at that point the
/// Hessian is garbage, not merely ill-conditioned.
pub fn stabilized_inverse_factor(h: &mut [f64], n: usize, damp_pct: f64) -> Vec<f64> {
    dampen(h, n, damp_pct);
    let mut pct = damp_pct;
    loop {
        match gptq_inverse_factor(h, n) {
            Some(u) => return u,
            None => {
                pct *= 10.0;
                assert!(pct < 1e6, "Hessian cannot be stabilized");
                dampen(h, n, pct);
            }
        }
    }
}

/// Dampen a (near-)SPD matrix in place: H += mean(diag(H)) * pct * I.
/// GPTQ uses pct = 0.01. Also replaces exactly-zero diagonal entries
/// ("dead" input features that never activated) with 1.0, matching the
/// reference implementation.
pub fn dampen(h: &mut [f64], n: usize, pct: f64) {
    let mut diag_mean = 0.0;
    for i in 0..n {
        if h[i * n + i] == 0.0 {
            h[i * n + i] = 1.0;
        }
        diag_mean += h[i * n + i];
    }
    diag_mean /= n as f64;
    let damp = diag_mean * pct;
    for i in 0..n {
        h[i * n + i] += damp;
    }
}

/// A·B for square f64 row-major (test helper and small-n uses).
pub fn matmul_f64(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    out
}

/// Build a random SPD matrix X·Xᵀ + eps·I from a source matrix (test aid and
/// Hessian shape: H = 2/m Σ x xᵀ).
pub fn gram(x: &Matrix, eps: f64) -> Vec<f64> {
    // x: m×n samples in rows; G = xᵀ·x / m
    let m = x.rows;
    let n = x.cols;
    let mut g = vec![0.0f64; n * n];
    for r in 0..m {
        let row = x.row(r);
        for i in 0..n {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in 0..n {
                g[i * n + j] += xi * row[j] as f64;
            }
        }
    }
    let inv_m = 1.0 / m.max(1) as f64;
    for v in g.iter_mut() {
        *v *= inv_m;
    }
    for i in 0..n {
        g[i * n + i] += eps;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(2 * n, n);
        rng.fill_normal(&mut x.data, 1.0);
        gram(&x, 1e-3)
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 8;
        let a = random_spd(n, 1);
        let l = cholesky(&a, n).expect("SPD");
        // L·Lᵀ == A
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn triangular_solves() {
        let n = 6;
        let a = random_spd(n, 2);
        let l = cholesky(&a, n).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64) - 2.0).collect();
        let y = solve_lower(&l, &b, n);
        let x = solve_lower_transpose(&l, &y, n);
        // A·x should equal b
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += a[i * n + j] * x[j];
            }
            assert!((s - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let n = 7;
        let a = random_spd(n, 3);
        let inv = spd_inverse(&a, n).unwrap();
        let prod = matmul_f64(&a, &inv, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[i * n + j] - want).abs() < 1e-7, "({i},{j})={}", prod[i * n + j]);
            }
        }
    }

    #[test]
    fn gptq_factor_squares_to_inverse() {
        let n = 5;
        let a = random_spd(n, 4);
        let u = gptq_inverse_factor(&a, n).unwrap();
        let inv = spd_inverse(&a, n).unwrap();
        // Uᵀ·U == A⁻¹
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[k * n + i] * u[k * n + j];
                }
                assert!((s - inv[i * n + j]).abs() < 1e-7);
            }
        }
        // U is upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn stabilized_factor_matches_plain_path_when_spd() {
        let n = 6;
        let a = random_spd(n, 5);
        // reference: dampen once, factor directly
        let mut ref_h = a.clone();
        dampen(&mut ref_h, n, 0.01);
        let want = gptq_inverse_factor(&ref_h, n).unwrap();
        let mut h = a;
        let got = stabilized_inverse_factor(&mut h, n, 0.01);
        assert_eq!(want, got);
    }

    #[test]
    fn stabilized_factor_escalates_on_indefinite_input() {
        // Eigenvalues 3 and -1: the initial 1% dampening cannot rescue the
        // factorization, so the ×10 escalation must kick in and eventually
        // deliver a valid upper-triangular factor.
        let n = 2;
        let mut h = vec![1.0, 2.0, 2.0, 1.0];
        let u = stabilized_inverse_factor(&mut h, n, 0.01);
        for i in 0..n {
            assert!(u[i * n + i] > 0.0, "diagonal must be positive");
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0, "U must be upper triangular");
            }
        }
    }

    #[test]
    fn dampen_fixes_dead_rows() {
        let n = 3;
        let mut h = vec![0.0f64; 9];
        h[0] = 4.0;
        h[4] = 0.0; // dead feature
        h[8] = 2.0;
        dampen(&mut h, n, 0.01);
        assert!(h[4] >= 1.0);
        assert!(cholesky(&h, n).is_some());
    }
}
