//! Dense matrix substrate. The GPTQ engine, the transformer forward pass,
//! and the evaluation harness all operate on row-major `f32` matrices; the
//! Hessian math additionally needs Cholesky factorization and triangular
//! solves, implemented in [`linalg`].

pub mod linalg;

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// Overwrite column `c`.
    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for (r, &x) in v.iter().enumerate() {
            *self.at_mut(r, c) = x;
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// self · other, blocked for cache friendliness (ikj loop order).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        matmul_into(
            &self.data,
            &other.data,
            &mut out.data,
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Element-wise maximum absolute value.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// self += s * other
    pub fn axpy(&mut self, s: f32, other: &Matrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }
}

/// out[m×n] = a[m×k] · b[k×n], row-major. ikj order: streams `b` rows, keeps
/// a scalar of `a` in register — the standard cache-friendly CPU pattern.
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (p, &a_ip) in a_row.iter().enumerate() {
            if a_ip == 0.0 {
                continue;
            }
            let b_row = &b[p * n..(p + 1) * n];
            for (o, &b_pj) in o_row.iter_mut().zip(b_row) {
                *o += a_ip * b_pj;
            }
        }
    }
}

/// y[m] = A[m×n] · x[n]
pub fn matvec_into(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (&w, &v) in row.iter().zip(x) {
            acc += w * v;
        }
        y[i] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_fn(3, 3, |r, c| (r * 3 + c) as f32);
        let i = Matrix::identity(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rectangular_product() {
        let a = Matrix::from_fn(2, 3, |r, c| (r + c) as f32);
        let b = Matrix::from_fn(3, 4, |r, c| (r * c) as f32);
        let c = a.matmul(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 4);
        // manual check of element (1,2): sum_k a[1,k]*b[k,2] = 1*0+2*2+3*4 = 16
        assert_eq!(c.at(1, 2), 16.0);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 7 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn col_set_get() {
        let mut a = Matrix::zeros(4, 3);
        a.set_col(1, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.col(1), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.col(0), vec![0.0; 4]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |r, c| (r as f32) - (c as f32) * 0.5);
        let x = vec![1.0f32, -2.0, 0.5];
        let mut y = vec![0.0f32; 4];
        matvec_into(&a.data, &x, &mut y, 4, 3);
        let xm = Matrix::from_vec(3, 1, x);
        let ym = a.matmul(&xm);
        for i in 0..4 {
            assert!((y[i] - ym.data[i]).abs() < 1e-6);
        }
    }
}
