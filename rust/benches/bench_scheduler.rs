//! Serving-runtime scenario bench: open-loop arrivals through the
//! continuous-batching scheduler vs. the lockstep (wave) baseline, on the
//! packed backend, at 1 / 8 / 32 concurrent slots — plus **shared-prefix**
//! cells where every prompt opens with the same system prompt, replayed
//! with the prefix cache off and on.
//!
//! Arrivals are Poisson in the *step domain* (a request becomes visible
//! just before a given engine step), with mean spacing chosen to keep the
//! live batch saturated, so results don't depend on wall-clock/machine
//! coupling; latency is still reported in wall time via a step→time map.
//! Open-loop means arrivals never wait for the engine — queueing delay is
//! part of p99. `CLAQ_BENCH_FAST=1` shrinks the trace. Results append to
//! `target/claq-bench.csv` and land in `BENCH_scheduler.json` at the repo
//! root (CI runs this bench and uploads the JSON; the shared-prefix cells
//! carry `prefill_in_per_req` / `saved_per_req` / `prefix_hits` extras so
//! the prefill-compute reduction at equal output is tracked run over run).
//! Every cell additionally carries `tok_s` and `bytes_decoded_per_s`
//! extras — generation throughput and the lower-bound decoded-LUT
//! bandwidth through the fused gather kernel selected by `CLAQ_KERNEL` —
//! plus paged-KV accounting: `kv_resident_bytes_per_req` (distinct pages,
//! shared pages counted once, at the resident high-water mark) against
//! `contiguous_kv_bytes_per_req` (what the pre-paging per-request
//! max-seq buffer cost), and `shared_kv_bytes_saved` (KV bytes a prefix
//! hit would have memcpy'd before page sharing — zero bytes are copied
//! now). A `kvq=8` shared-prefix cell runs with cold-page KV
//! quantization on; it is lossy by design, so no token-equality assert.

use claq::model::exec::{ExecModel, ExecState, KvCache};
use claq::model::linear::KernelKind;
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::scheduler::{
    AdmissionPolicy, Request, Scheduler, SchedulerConfig, SchedulerStats,
};
use claq::util::benchlib::{append_csv, write_bench_json, Sample};
use claq::util::rng::Rng;
use claq::util::threadpool::ThreadPool;
use std::time::Instant;

struct ScenarioResult {
    tok_per_s: f64,
    ttft_p50_ms: f64,
    tok_p99_ms: f64,
    wall_ns: f64,
    generated: u64,
    requests: u64,
    /// Engine steps that did work (each runs ≥1 fused forward pass, so
    /// each decodes the model's full LUT plane set at least once).
    engine_steps: u64,
    stats: SchedulerStats,
    /// id → tokens, for cross-scenario agreement checks.
    outputs: Vec<(u64, Vec<u16>)>,
}

/// Replay one step-domain arrival trace and measure wall-side stats.
fn run_scenario(
    model: &ExecModel,
    arrivals: &[(usize, Request)],
    slots: usize,
    policy: AdmissionPolicy,
    prefix_cache_bytes: usize,
    // (page_tokens, quant_bits, quant_margin); (0, 0, _) = default pages,
    // quantization off.
    kv: (usize, u8, usize),
    // (kv_budget_bytes, max_queue); (0, 0) = unbounded (no overload).
    overload: (usize, usize),
) -> ScenarioResult {
    let mut st = ExecState::new(model.config);
    let mut b = SchedulerConfig::builder()
        .max_slots(slots)
        .prefill_token_budget(2 * model.config.max_seq)
        .policy(policy)
        .prefix_cache_bytes(prefix_cache_bytes)
        .kv_page_tokens(kv.0)
        .kv_quant_bits(kv.1)
        .kv_budget_bytes(overload.0)
        .max_queue(overload.1);
    // The builder rejects a quantizer margin with quantization off, so a
    // margin is forwarded only for kvq scenarios.
    if kv.1 > 0 {
        b = b.kv_quant_margin(kv.2);
    }
    let mut sched = Scheduler::new(model.config, b.build().expect("bench scenario config"));
    let mut completions = Vec::new();
    let mut step_wall = Vec::new();
    let mut submit_wall = vec![0.0f64; arrivals.len()]; // indexed by id
    let mut next = 0usize;
    let mut step = 0usize;
    let t0 = Instant::now();
    while next < arrivals.len() || sched.has_work() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            let id = sched.submit(arrivals[next].1.clone()).expect("bench request valid");
            submit_wall[id as usize] = t0.elapsed().as_secs_f64();
            next += 1;
        }
        if sched.has_work() {
            completions.extend(sched.step(model, &mut st));
            step_wall.push(t0.elapsed().as_secs_f64());
        }
        step += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let mut generated = 0usize;
    let mut ttft_ms = Vec::new();
    let mut tok_ms = Vec::new();
    let mut outputs = Vec::with_capacity(completions.len());
    for c in &completions {
        generated += c.tokens.len();
        // Shed before admission (rejected / queued expiry): no engine
        // step ever ran it, so there is no TTFT to index step_wall with.
        if c.admitted_step == 0 {
            continue;
        }
        let first = step_wall[c.admitted_step as usize - 1];
        let last = step_wall[c.finished_step as usize - 1];
        ttft_ms.push((first - submit_wall[c.id as usize]) * 1e3);
        if c.tokens.len() > 1 {
            tok_ms.push((last - first) * 1e3 / (c.tokens.len() - 1) as f64);
        }
        outputs.push((c.id, c.tokens.clone()));
    }
    outputs.sort_by_key(|(id, _)| *id);
    ttft_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    tok_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |xs: &[f64], p: f64| {
        if xs.is_empty() {
            f64::NAN
        } else {
            xs[((xs.len() - 1) as f64 * p) as usize]
        }
    };
    ScenarioResult {
        tok_per_s: generated as f64 / wall_s,
        ttft_p50_ms: pick(&ttft_ms, 0.5),
        tok_p99_ms: pick(&tok_ms, 0.99),
        wall_ns: wall_s * 1e9,
        generated: generated as u64,
        requests: arrivals.len() as u64,
        engine_steps: step_wall.len() as u64,
        stats: sched.stats(),
        outputs,
    }
}

/// One JSON cell: total scenario wall time over generated tokens, so
/// `ns_per_elem` is ns per generated token — comparable with the decode
/// bench rows.
fn sample(
    name: &str,
    r: &ScenarioResult,
    plane_bytes_per_step: f64,
    contiguous_kv_bytes: f64,
) -> Sample {
    let per_req = |x: u64| x as f64 / r.requests as f64;
    let wall_s = r.wall_ns * 1e-9;
    // Lower-bound decoded-LUT bandwidth: every working engine step runs at
    // least one fused forward pass, and each pass decodes the model's full
    // plane set once (prefill sub-steps in the same engine step add more,
    // so the true figure is ≥ this).
    let bytes_decoded_per_s = r.engine_steps as f64 * plane_bytes_per_step / wall_s;
    // Distinct-page residency at the high-water mark, amortised over the
    // concurrent requests live at that point; contrast with what a
    // contiguous max-seq buffer per request would have pinned.
    let kv_resident_per_req =
        r.stats.peak_kv_resident_bytes as f64 / r.stats.peak_live.max(1) as f64;
    Sample {
        name: name.to_string(),
        iters: 1,
        median_ns: r.wall_ns,
        mad_ns: 0.0,
        mean_ns: r.wall_ns,
        elems: Some(r.generated),
        extra: vec![
            ("requests".into(), r.requests as f64),
            ("generated_per_req".into(), per_req(r.generated)),
            ("prefill_in_per_req".into(), per_req(r.stats.prefill_tokens_in)),
            ("saved_per_req".into(), per_req(r.stats.prefill_tokens_saved)),
            ("prefix_hits".into(), r.stats.prefix_hits as f64),
            ("tok_s".into(), r.tok_per_s),
            ("bytes_decoded_per_s".into(), bytes_decoded_per_s),
            ("kv_resident_bytes_per_req".into(), kv_resident_per_req),
            ("contiguous_kv_bytes_per_req".into(), contiguous_kv_bytes),
            ("shared_kv_bytes_saved".into(), r.stats.shared_kv_bytes_saved as f64),
            ("kv_pages_quantized".into(), r.stats.kv_pages_quantized_total as f64),
            // Overload accounting (informational extras; all 0 in the
            // unbounded cells): how many requests the ladder shed or
            // preempted-and-resumed under a KV budget / queue bound.
            ("rejected".into(), r.stats.rejected as f64),
            ("preempted".into(), r.stats.preempted as f64),
            ("resumed".into(), r.stats.resumed as f64),
        ],
    }
}

fn main() {
    let fast = std::env::var("CLAQ_BENCH_FAST").is_ok();
    let cfg = TransformerConfig::tiny_l();
    let model = Model::random(cfg, &mut Rng::new(6));
    let packed =
        QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12()).to_exec();
    let plane_bytes = packed.decoded_plane_bytes_per_step() as f64;
    let contiguous_kv = KvCache::contiguous_bytes(&cfg) as f64;
    println!(
        "== bench group: scheduler ==  (packed backend, {} gather kernel, {} kernel threads{})",
        KernelKind::from_env().name(),
        ThreadPool::global().workers(),
        if fast { ", fast mode" } else { "" }
    );

    let mut csv_rows: Vec<String> = Vec::new();
    let mut samples: Vec<Sample> = Vec::new();
    for &conc in &[1usize, 8, 32] {
        // Trace: enough requests to reach steady state; Poisson arrival
        // gaps with mean ~ mean_service/conc keep the batch saturated.
        let n_requests = conc * if fast { 3 } else { 6 };
        let mut rng = Rng::new(40 + conc as u64);
        let mut arrivals = Vec::with_capacity(n_requests);
        let mut at = 0.0f64;
        let mean_new = 24.0;
        for i in 0..n_requests {
            at += -rng.next_f64().max(1e-12).ln() * mean_new / conc as f64;
            let prompt_len = 8 + rng.below_usize(25); // 8..=32
            let max_new = 8 + rng.below_usize(33); // 8..=40
            let prompt: Vec<u16> =
                (0..prompt_len).map(|_| ((i * 31 + 7) % cfg.vocab) as u16).collect();
            arrivals.push((
                at as usize,
                Request { prompt, max_new_tokens: max_new, stop_token: None },
            ));
        }

        let cont = run_scenario(
            &packed,
            &arrivals,
            conc,
            AdmissionPolicy::Continuous,
            0,
            (0, 0, 0),
            (0, 0),
        );
        let wave =
            run_scenario(&packed, &arrivals, conc, AdmissionPolicy::Wave, 0, (0, 0, 0), (0, 0));
        println!(
            "concurrency {conc:>2}: continuous {:>8.0} tok/s (ttft p50 {:>6.1} ms, tok p99 {:>6.2} ms)",
            cont.tok_per_s, cont.ttft_p50_ms, cont.tok_p99_ms
        );
        println!(
            "                lockstep   {:>8.0} tok/s (ttft p50 {:>6.1} ms, tok p99 {:>6.2} ms)  ->  {:.2}× continuous",
            wave.tok_per_s,
            wave.ttft_p50_ms,
            wave.tok_p99_ms,
            cont.tok_per_s / wave.tok_per_s
        );
        for (policy, r) in [("continuous", &cont), ("lockstep", &wave)] {
            // one row per scenario; the time column is ns per generated
            // token so it is comparable with the decode bench rows
            let ns_per_tok = 1e9 / r.tok_per_s;
            csv_rows.push(format!(
                "scheduler,{policy} conc={conc},{ns_per_tok:.1},0.0,{ns_per_tok:.1},1"
            ));
            samples.push(sample(&format!("{policy} conc={conc}"), r, plane_bytes, contiguous_kv));
        }
    }

    // --- shared-prefix cells: identical system prompt, cache off vs on ---
    // Requests arrive staggered so retirements can seed later admissions;
    // outputs must be token-identical either way (the prefix cache only
    // changes *where* prompt K/V comes from), while prefill tokens per
    // request drop by roughly the shared-prefix length.
    let conc = 8usize;
    let n_requests = conc * if fast { 3 } else { 6 };
    let sys_len = 24usize;
    let mut rng = Rng::new(77);
    let system: Vec<u16> = (0..sys_len).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
    let mut arrivals = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let tail_len = 4 + rng.below_usize(9); // 4..=12
        let mut prompt = system.clone();
        prompt.extend((0..tail_len).map(|_| rng.below(cfg.vocab as u64) as u16));
        let max_new = 8 + rng.below_usize(17); // 8..=24
        // arrivals spaced a few steps apart: the first retirement lands
        // before the trace ends, so later admissions can hit
        arrivals.push((3 * i, Request { prompt, max_new_tokens: max_new, stop_token: None }));
    }
    let cold = run_scenario(
        &packed,
        &arrivals,
        conc,
        AdmissionPolicy::Continuous,
        0,
        (0, 0, 0),
        (0, 0),
    );
    let warm = run_scenario(
        &packed,
        &arrivals,
        conc,
        AdmissionPolicy::Continuous,
        64 << 20,
        (0, 0, 0),
        (0, 0),
    );
    assert_eq!(cold.outputs, warm.outputs, "prefix cache changed token streams");
    assert!(warm.stats.prefix_hits > 0, "shared-prefix trace produced no prefix hits");
    // Page sharing means a hit is O(pages) refcount bumps: the bytes the
    // old contiguous fork memcpy'd are now *saved*, and no stat anywhere
    // counts a KV copy on the hit path.
    assert!(
        warm.stats.shared_kv_bytes_saved > 0,
        "prefix hits should report KV bytes saved by page sharing"
    );
    // Cold-page KV quantization on top of the same trace: lossy by
    // design (tolerance-gated in tests/paged_kv.rs), so throughput and
    // residency are tracked but token streams are NOT asserted equal.
    // 16-token pages + 16-token margin make pages actually go cold at
    // this trace's sequence lengths (≤ ~60 of max_seq 128).
    let kvq = run_scenario(
        &packed,
        &arrivals,
        conc,
        AdmissionPolicy::Continuous,
        64 << 20,
        (16, 8, 16),
        (0, 0),
    );
    assert!(
        kvq.stats.kv_pages_quantized_total > 0,
        "quantized-KV cell re-encoded no cold pages"
    );
    for (label, r) in [("cache=off", &cold), ("cache=on", &warm), ("cache=on kvq=8", &kvq)] {
        println!(
            "shared-prefix conc={conc} {label}: {:>8.0} tok/s, prefill in/req {:>5.1}, \
             saved/req {:>5.1}, hits {}, kv peak/req {:.1} KB (contiguous {:.1} KB)",
            r.tok_per_s,
            r.stats.prefill_tokens_in as f64 / r.requests as f64,
            r.stats.prefill_tokens_saved as f64 / r.requests as f64,
            r.stats.prefix_hits,
            r.stats.peak_kv_resident_bytes as f64 / r.stats.peak_live.max(1) as f64 / 1024.0,
            contiguous_kv / 1024.0,
        );
        let ns_per_tok = 1e9 / r.tok_per_s;
        csv_rows.push(format!(
            "scheduler,sharedprefix conc={conc} {label},{ns_per_tok:.1},0.0,{ns_per_tok:.1},1"
        ));
        samples.push(sample(
            &format!("sharedprefix conc={conc} {label}"),
            r,
            plane_bytes,
            contiguous_kv,
        ));
    }

    // --- overload cell: the same staggered trace squeezed under a
    // 12-page KV budget (vs ~32 pages the 8 live slots would like) and a
    // 4-deep queue bound. With no prefix cache and no KV quantization the
    // only relief rung is preemption, so the cell exercises the
    // preempt/resume path end to end; `tok_s` gates as a floor so the
    // ladder can't quietly collapse into thrash (DESIGN.md §14).
    let page_tokens = 16usize;
    let page_bytes = 2 * cfg.n_layers * page_tokens * cfg.d_model * std::mem::size_of::<f32>();
    let over = run_scenario(
        &packed,
        &arrivals,
        conc,
        AdmissionPolicy::Continuous,
        0,
        (page_tokens, 0, 0),
        (12 * page_bytes, 4),
    );
    assert!(over.stats.preempted > 0, "overload cell never preempted — budget not binding");
    assert_eq!(over.stats.resumed, over.stats.preempted, "a drained bench resumed every preempt");
    assert_eq!(
        over.stats.completed + over.stats.rejected,
        over.requests,
        "every overload request must resolve as completed or rejected"
    );
    assert_eq!(
        over.stats.pool_free_pages as u64, over.stats.pool_pages_created,
        "overload run leaked pages"
    );
    println!(
        "overload conc={conc} budget-pages=12 queue=4: {:>8.0} tok/s, {} completed, \
         {} rejected, {} preemptions / {} resumes",
        over.tok_per_s,
        over.stats.completed,
        over.stats.rejected,
        over.stats.preempted,
        over.stats.resumed
    );
    let ns_per_tok = 1e9 / over.tok_per_s;
    csv_rows.push(format!(
        "scheduler,overload conc={conc} budget-pages=12 queue=4,{ns_per_tok:.1},0.0,{ns_per_tok:.1},1"
    ));
    samples.push(sample(
        &format!("overload conc={conc} budget-pages=12 queue=4"),
        &over,
        plane_bytes,
        contiguous_kv,
    ));

    append_csv(&csv_rows);
    match write_bench_json("scheduler", &samples) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write BENCH_scheduler.json: {e}"),
    }
}
