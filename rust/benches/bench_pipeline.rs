//! End-to-end pipeline bench (the Table 1 inner loop): calibrate +
//! quantize a whole model, and the evaluation passes — the costs that
//! bound how fast the table harness regenerates the paper.

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::eval::perplexity::perplexity;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("pipeline");
    let cfg = TransformerConfig {
        vocab: VOCAB,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 96,
        max_seq: 64,
        rope_theta: 10000.0,
        eps: 1e-5,
    };
    let model = Model::random(cfg, &mut Rng::new(3));
    let stream = generate(CorpusKind::SynthC4, 30_000, 1);
    let calib = sample_segments(&stream, &CalibConfig { n_segments: 8, seq_len: 64, seed: 1 });
    let heldout = generate(CorpusKind::SynthC4, 64 * 8, 2);

    for method in [Method::Rtn { bits: 2 }, Method::Claq { bits: 2 }, Method::fusion_2_12()] {
        b.run(&format!("quantize_model {}", method.name()), || {
            black_box(quantize_model(
                black_box(&model),
                &method,
                &calib,
                &PipelineOpts::default(),
            ));
        });
    }

    // §Perf ablation: incremental layer-state calibration vs full
    // re-forward per layer (same quantized output, different work).
    for incremental in [false, true] {
        let opts = PipelineOpts { incremental, ..Default::default() };
        let tag = if incremental { "incremental" } else { "re-forward" };
        b.run(&format!("calibration {} CLAQ-2", tag), || {
            black_box(quantize_model(black_box(&model), &Method::Claq { bits: 2 }, &calib, &opts));
        });
    }

    b.run_with_elems("perplexity 8 windows", Some((64 * 8) as u64), || {
        black_box(perplexity(black_box(&model), &heldout, 0));
    });

    b.finish();
}
