//! L3 hot-path bench: the GPTQ engine end to end on one matrix — Hessian
//! factorization + per-column quantize + OBS error propagation, the inner
//! loop behind every Table-1 row. Cells cover the model's real matrix
//! shapes and both centroid rules.

use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
use claq::tensor::linalg::gram;
use claq::tensor::Matrix;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("gptq");
    let mut rng = Rng::new(2);
    // (rows, cols) mirror tiny-L / tiny-XL projection shapes
    for &(rows, cols) in &[(128usize, 128usize), (352, 128), (192, 192)] {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.02);
        let mut x = Matrix::zeros(256, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }
        let elems = (rows * cols) as u64;
        for (name, rule) in [("kmeans", CentroidRule::KMeans), ("uniform", CentroidRule::UniformMinMax)] {
            let plan = MatrixPlan::uniform(cols, 2, rule, true);
            b.run_with_elems(
                &format!("quantize {rows}x{cols} 2b {name}+OBS"),
                Some(elems),
                || {
                    black_box(quantize_matrix(black_box(&w), Some(&h), &plan));
                },
            );
        }
        // no-propagation variant isolates the OBS update cost
        let plan_rtn = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
        b.run_with_elems(
            &format!("quantize {rows}x{cols} 2b kmeans no-OBS"),
            Some(elems),
            || {
                black_box(quantize_matrix(black_box(&w), None, &plan_rtn));
            },
        );
    }
    b.finish();
}
