//! L3 hot-path bench: the GPTQ engine end to end on one matrix — Hessian
//! factorization + per-column quantize + OBS error propagation, the inner
//! loop behind every Table-1 row. Cells cover the model's real matrix
//! shapes plus production-size ≥512-column shapes, where the blocked
//! lazy-batch OBS path (DESIGN.md §8) is compared against the unblocked
//! baseline (`block_size = 0`) — the tracked single-matrix speedup.
//! Results land in `target/claq-bench.csv` and `BENCH_gptq.json` at the
//! repo root (CI runs this bench in `CLAQ_BENCH_FAST` mode every push).

use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan, DEFAULT_BLOCK};
use claq::tensor::linalg::gram;
use claq::tensor::Matrix;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn hessian(cols: usize, samples: usize, rng: &mut Rng) -> Vec<f64> {
    let mut x = Matrix::zeros(samples, cols);
    rng.fill_normal(&mut x.data, 1.0);
    let mut h = gram(&x, 0.0);
    for v in h.iter_mut() {
        *v *= 2.0;
    }
    h
}

fn main() {
    let mut b = Bench::new("gptq");
    let mut rng = Rng::new(2);
    // (rows, cols) mirror tiny-L / tiny-XL projection shapes
    for &(rows, cols) in &[(128usize, 128usize), (352, 128), (192, 192)] {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.02);
        // 256 calibration samples, matching the pre-blocking bench cells so
        // the target/claq-bench.csv history stays comparable.
        let h = hessian(cols, 256, &mut rng);
        let elems = (rows * cols) as u64;
        for (name, rule) in [("kmeans", CentroidRule::KMeans), ("uniform", CentroidRule::UniformMinMax)] {
            let plan = MatrixPlan::uniform(cols, 2, rule, true);
            b.run_with_elems(
                &format!("quantize {rows}x{cols} 2b {name}+OBS"),
                Some(elems),
                || {
                    black_box(quantize_matrix(black_box(&w), Some(&h), &plan));
                },
            );
        }
        // no-propagation variant isolates the OBS update cost
        let plan_rtn = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
        b.run_with_elems(
            &format!("quantize {rows}x{cols} 2b kmeans no-OBS"),
            Some(elems),
            || {
                black_box(quantize_matrix(black_box(&w), None, &plan_rtn));
            },
        );
    }

    // Production-size cells: the unblocked baseline re-sweeps the whole
    // rows×trailing working set for every column (cache-hostile once it
    // spills L2), while the blocked path keeps a B-column window resident
    // and row-shards one trailing rank-B update per block. Adjacent cells
    // record the tracked blocked-vs-unblocked speedup.
    for &(rows, cols) in &[(512usize, 512usize), (2048, 512)] {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.02);
        // 2·cols samples keep the gram full rank at these widths
        let h = hessian(cols, 2 * cols, &mut rng);
        let elems = (rows * cols) as u64;
        let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, true);
        for (label, block) in
            [("unblocked", 0usize), ("b16", 16), ("b64", DEFAULT_BLOCK), ("b256", 256)]
        {
            plan.block_size = block;
            b.run_with_elems(
                &format!("quantize {rows}x{cols} 2b kmeans+OBS {label}"),
                Some(elems),
                || {
                    black_box(quantize_matrix(black_box(&w), Some(&h), &plan));
                },
            );
        }
    }
    b.finish();
}
