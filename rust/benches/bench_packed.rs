//! Deployment-format bench: bit-packing, container serialize/parse, and
//! dequantization — the runtime costs of the packed CLAQ container.

use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan};
use claq::quant::packed::{
    decode_plane_tile_into, pack, pack_indices, unpack, unpack_indices, unpack_indices_range_into,
};
use claq::tensor::Matrix;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("packed");
    let mut rng = Rng::new(4);

    for &bits in &[2u8, 3, 4] {
        let n = 16_384;
        let idx: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
        b.run_with_elems(&format!("pack_indices {bits}b n={n}"), Some(n as u64), || {
            black_box(pack_indices(black_box(&idx), bits));
        });
        let packed = pack_indices(&idx, bits);
        b.run_with_elems(&format!("unpack_indices {bits}b n={n}"), Some(n as u64), || {
            black_box(unpack_indices(black_box(&packed), bits, n));
        });
        // bulk range unpack: the word-at-a-time path the tiled gather
        // kernel runs on, into a preallocated buffer (no per-call Vec)
        let mut idx_out = vec![0u8; n];
        b.run_with_elems(&format!("bulk_unpack {bits}b n={n}"), Some(n as u64), || {
            unpack_indices_range_into(black_box(&packed), bits, 0, black_box(&mut idx_out));
        });
        // LUT gather on top of the bulk unpack: packed plane -> f32 column
        let centroids: Vec<f32> = (0..1u16 << bits).map(|i| i as f32 * 0.25 - 1.0).collect();
        let mut col = vec![0.0f32; n];
        b.run_with_elems(&format!("tile_decode {bits}b n={n}"), Some(n as u64), || {
            decode_plane_tile_into(black_box(&packed), bits, &centroids, 0, black_box(&mut col));
        });
    }

    // whole-matrix container round trip at tiny-L shape
    let mut w = Matrix::zeros(128, 128);
    rng.fill_normal(&mut w.data, 0.02);
    let mut plan = MatrixPlan::uniform(128, 2, CentroidRule::KMeans, false);
    plan.reserve = vec![2; 128];
    let qm = quantize_matrix(&w, None, &plan);
    let elems = (128 * 128) as u64;
    b.run_with_elems("pack 128x128 fusion", Some(elems), || {
        black_box(pack(black_box(&qm)).unwrap());
    });
    let (pm, _) = pack(&qm).unwrap();
    b.run_with_elems("unpack 128x128 fusion", Some(elems), || {
        black_box(unpack(black_box(&pm)).unwrap());
    });
    b.run_with_elems("dequantize 128x128", Some(elems), || {
        black_box(qm.dequantize());
    });

    // vector-quantized planes at the same shape: R^4 k-means encode (the
    // group quantizer), CLAQVQ01 serialize/parse, and the grouped
    // dequantize — 2-bit indices over 4-wide groups = 0.5 index b/param.
    let vq_plan = MatrixPlan::vector_group(128, 4, 2, true);
    b.run_with_elems("vq_quantize 128x128 d4 2b", Some(elems), || {
        black_box(quantize_matrix(black_box(&w), None, &vq_plan));
    });
    let vqm = quantize_matrix(&w, None, &vq_plan);
    b.run_with_elems("vq_pack 128x128 d4 2b", Some(elems), || {
        black_box(pack(black_box(&vqm)).unwrap());
    });
    let (vpm, _) = pack(&vqm).unwrap();
    b.run_with_elems("vq_unpack 128x128 d4 2b", Some(elems), || {
        black_box(unpack(black_box(&vpm)).unwrap());
    });
    b.run_with_elems("vq_dequantize 128x128 d4 2b", Some(elems), || {
        black_box(vqm.dequantize());
    });
    b.finish();
}
