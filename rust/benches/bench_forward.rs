//! Inference-path bench: the pure-Rust forward pass and, when artifacts
//! exist, the PJRT execution of the AOT JAX/Pallas graph — the serving
//! hot path of `examples/serve_quantized.rs`.

use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::model::forward::{forward, ForwardState};
use claq::model::io::load_model;
use claq::model::{Model, TransformerConfig};
use claq::runtime::executor::ModelExecutor;
use claq::runtime::Runtime;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;
use std::path::PathBuf;

fn main() {
    let mut b = Bench::new("forward");

    let cfg = TransformerConfig::tiny_l();
    let model = Model::random(cfg, &mut Rng::new(5));
    let tokens = generate(CorpusKind::SynthC4, cfg.max_seq, 1);
    assert!(tokens.iter().all(|&t| (t as usize) < VOCAB));
    let mut state = ForwardState::new(cfg);
    let toks = (cfg.max_seq) as u64;
    b.run_with_elems("rust forward tiny-L seq=128", Some(toks), || {
        black_box(forward(black_box(&model), &tokens, &mut state));
    });

    // PJRT path needs artifacts
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("model_l.hlo.txt").exists() && dir.join("weights_l.bin").exists() {
        let trained = load_model(&dir.join("weights_l.bin")).unwrap();
        let mut rt = Runtime::cpu().unwrap();
        let mut exec = ModelExecutor::new(dir.join("model_l.hlo.txt"), &trained).unwrap();
        let _ = exec.logits(&mut rt, &tokens).unwrap(); // compile warm-up
        b.run_with_elems("pjrt forward tiny-L seq=128", Some(toks), || {
            black_box(exec.logits(&mut rt, black_box(&tokens)).unwrap());
        });
    } else {
        eprintln!("(skipping PJRT forward bench: run `make artifacts` first)");
    }
    b.finish();
}
