//! L3 hot-path bench: per-column K-Means codebook construction (§3.1) —
//! the dominant cost of CLAQ quantization. One row per (column height ×
//! bit width) cell; throughput is weights clustered per second.

use claq::quant::kmeans::{kmeans_1d, KMeansOpts};
use claq::util::benchlib::{black_box, Bench};
use claq::util::proptest::gen_column;
use claq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("kmeans");
    let mut rng = Rng::new(1);
    for &n in &[128usize, 512, 2048] {
        for &bits in &[2u32, 3, 4] {
            let col = gen_column(&mut rng, n, 0.02);
            let opts = KMeansOpts::default();
            b.run_with_elems(&format!("kmeans_1d n={n} bits={bits}"), Some(n as u64), || {
                black_box(kmeans_1d(black_box(&col), 1 << bits, &opts));
            });
        }
    }
    // uniform codebook as the comparison point (RTN centroid rule)
    for &n in &[2048usize] {
        let col = gen_column(&mut rng, n, 0.02);
        b.run_with_elems(&format!("uniform_codebook n={n} k=8"), Some(n as u64), || {
            black_box(claq::quant::codebook::uniform_codebook(black_box(&col), 8));
        });
    }
    b.finish();
}
