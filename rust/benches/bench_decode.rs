//! Decode-path bench: packed vs dense KV-cached decode throughput
//! (tokens/s at batch 1/4/16) — tracks the serving hot path of
//! `examples/serve_quantized.rs` in `target/claq-bench.csv` (throughput is
//! reported as Melem/s where an "elem" is one decoded token). The packed
//! backend runs twice, once per gather kernel: the tiled kernel under the
//! historical "packed ..." cell names and the pinned scalar kernel under
//! "packed[scalar] ..." so one `BENCH_decode.json` shows both side by
//! side. A third quantization of the same model under `Method::ClaqVq`
//! runs as "packed[vq] ..." — the fused grouped-gather kernel over
//! CLAQVQ01 vector planes, whose `bytes_decoded_per_s` numerator is d×
//! smaller per step (one index plane per column group). A fourth,
//! "packed[ap-2.12] ...", quantizes with pure adaptive precision
//! (`claq-ap:2+4@2.12`, parsed through the typed spec grammar): every
//! projection carries mixed per-column bit planes with no outlier
//! reservation, so these cells isolate the equal-bit-run decode path of
//! the mixed-bit kernels. Packed cells carry `tok_s` and `bytes_decoded_per_s` extras
//! (decoded-LUT bandwidth through the gather kernel) — plus the
//! cold-start cells: the model is packed into a single-file CLAQMD01
//! checkpoint, reloaded, smoke-tested with a 3-step decode, and timed
//! load→ready and load→first-token. The `coldstart` cells carry the
//! checkpoint file size as their `elems`, so `BENCH_decode.json` tracks
//! artifact-size regressions alongside latency (CI uploads it).

use claq::model::exec::{decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::linear::KernelKind;
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::{Method, MethodSpec};
use claq::runtime::executor::ColdStart;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn bench_backend(b: &mut Bench, em: &ExecModel, label: &str) {
    let cfg = em.config;
    let prompt_len = 32usize;
    let mut state = ExecState::new(cfg);
    let prompt: Vec<u16> = (0..prompt_len as u16).map(|i| (i * 7) % cfg.vocab as u16).collect();
    // Every projection decodes its full LUT plane set exactly once per
    // forward pass (prefill or decode step alike), so each bench iteration
    // moves this many decoded bytes through the gather kernel. Dense
    // backends report 0 and skip the extra.
    let plane_bytes = em.decoded_plane_bytes_per_step() as f64;

    b.run_with_elems(&format!("{label} prefill seq={prompt_len}"), Some(prompt_len as u64), || {
        let mut cache = KvCache::new(&cfg);
        black_box(prefill(em, &mut cache, &prompt, &mut state));
    });
    b.annotate_rate("tok_s", prompt_len as f64);
    if plane_bytes > 0.0 {
        b.annotate_rate("bytes_decoded_per_s", plane_bytes);
    }

    for &batch in &[1usize, 4, 16] {
        let mut caches: Vec<KvCache> = (0..batch)
            .map(|_| {
                let mut c = KvCache::new(&cfg);
                let _ = prefill(em, &mut c, &prompt, &mut state);
                c
            })
            .collect();
        let toks: Vec<u16> = (0..batch as u16).map(|i| i % cfg.vocab as u16).collect();
        b.run_with_elems(&format!("{label} decode batch={batch}"), Some(batch as u64), || {
            if caches[0].len() >= cfg.max_seq {
                for c in caches.iter_mut() {
                    c.truncate(prompt_len);
                }
            }
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            black_box(decode_step(em, &mut refs, &toks, &mut state));
        });
        b.annotate_rate("tok_s", batch as f64);
        if plane_bytes > 0.0 {
            b.annotate_rate("bytes_decoded_per_s", plane_bytes);
        }
    }
}

fn main() {
    let mut b = Bench::new("decode");
    let cfg = TransformerConfig::tiny_l();
    let model = Model::random(cfg, &mut Rng::new(6));
    let qm = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12());

    // Side-by-side kernels in one run: the tiled kernel keeps the
    // historical "packed ..." cell names (so the CI baseline gate keeps
    // tracking the shipping default), the pinned scalar kernel lands in
    // fresh "packed[scalar] ..." cells for in-run comparison.
    let packed = qm.to_exec_kernel(KernelKind::Tiled);
    let packed_scalar = qm.to_exec_kernel(KernelKind::Scalar);
    let dense = ExecModel::dense(&qm.to_dense());
    // Same model through the vector-quantized plane kind: 2-bit indices
    // over 4-wide column groups = 0.5 index bits/param.
    let qvq = QuantizedModel::quantize_uncalibrated(&model, &Method::ClaqVq { d: 4, bits: 2 });
    let packed_vq = qvq.to_exec_kernel(KernelKind::Tiled);
    // Pure adaptive precision through the typed spec grammar: mixed
    // per-column bits on every projection, no outlier reservation.
    let ap_spec: MethodSpec = "claq-ap:2+4@2.12".parse().expect("ap bench spec");
    let qap = QuantizedModel::quantize_uncalibrated(&model, ap_spec.method());
    let packed_ap = qap.to_exec_kernel(KernelKind::Tiled);
    println!(
        "projection weights: packed {:.2} MB vs vq {:.2} MB vs dense {:.2} MB",
        packed.projection_bytes() as f64 / 1e6,
        packed_vq.projection_bytes() as f64 / 1e6,
        dense.projection_bytes() as f64 / 1e6
    );

    bench_backend(&mut b, &packed, "packed");
    bench_backend(&mut b, &packed_scalar, "packed[scalar]");
    bench_backend(&mut b, &packed_vq, "packed[vq]");
    bench_backend(&mut b, &packed_ap, "packed[ap-2.12]");
    bench_backend(&mut b, &dense, "dense");

    // --- cold start: checkpoint -> packed engine ---------------------------
    let ckpt_path = claq::util::tmp::unique_path("bench_decode_ckpt").with_extension("claq");
    let bytes = {
        qm.save(&ckpt_path).expect("write bench checkpoint");
        std::fs::metadata(&ckpt_path).expect("stat bench checkpoint").len()
    };
    println!(
        "checkpoint on disk: {:.2} MB ({bytes} bytes) — coldstart cells report bytes as elems",
        bytes as f64 / 1e6
    );

    // reload + 3-step decode smoke: the artifact must serve, not just parse
    {
        let cold = ColdStart::from_path(&ckpt_path).expect("cold start");
        assert_eq!(cold.checkpoint_bytes, bytes);
        let mut st = ExecState::new(cold.exec.config);
        let mut cache = KvCache::new(&cold.exec.config);
        let logits = prefill(&cold.exec, &mut cache, &[1, 2, 3, 4], &mut st);
        let mut tok = claq::model::exec::argmax(logits.row(3));
        for _ in 0..3 {
            let logits = decode_step(&cold.exec, &mut [&mut cache], &[tok], &mut st);
            assert!(logits.data.iter().all(|v| v.is_finite()), "cold-start decode produced non-finite logits");
            tok = claq::model::exec::argmax(logits.row(0));
        }
    }

    // load -> ready ExecModel (elems/s here is effective load bandwidth)
    b.run_with_elems("coldstart load->exec", Some(bytes), || {
        black_box(ColdStart::from_path(&ckpt_path).expect("cold start"));
    });

    // load -> first token: checkpoint read, plane parse, engine build, and
    // one single-token prefill — the serve-from-zero latency
    b.run_with_elems("coldstart load->first-token", Some(bytes), || {
        let cold = ColdStart::from_path(&ckpt_path).expect("cold start");
        let mut st = ExecState::new(cold.exec.config);
        let mut cache = KvCache::new(&cold.exec.config);
        black_box(prefill(&cold.exec, &mut cache, &[1u16], &mut st));
    });

    let _ = std::fs::remove_file(&ckpt_path);
    b.finish();
}
