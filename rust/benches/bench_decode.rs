//! Decode-path bench: packed vs dense KV-cached decode throughput
//! (tokens/s at batch 1/4/16) — tracks the serving hot path of
//! `examples/serve_quantized.rs` in `target/claq-bench.csv` (throughput is
//! reported as Melem/s where an "elem" is one decoded token).

use claq::model::exec::{decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::util::benchlib::{black_box, Bench};
use claq::util::rng::Rng;

fn bench_backend(b: &mut Bench, em: &ExecModel, label: &str) {
    let cfg = em.config;
    let prompt_len = 32usize;
    let mut state = ExecState::new(cfg);
    let prompt: Vec<u16> = (0..prompt_len as u16).map(|i| (i * 7) % cfg.vocab as u16).collect();

    b.run_with_elems(&format!("{label} prefill seq={prompt_len}"), Some(prompt_len as u64), || {
        let mut cache = KvCache::new(&cfg);
        black_box(prefill(em, &mut cache, &prompt, &mut state));
    });

    for &batch in &[1usize, 4, 16] {
        let mut caches: Vec<KvCache> = (0..batch)
            .map(|_| {
                let mut c = KvCache::new(&cfg);
                let _ = prefill(em, &mut c, &prompt, &mut state);
                c
            })
            .collect();
        let toks: Vec<u16> = (0..batch as u16).map(|i| i % cfg.vocab as u16).collect();
        b.run_with_elems(&format!("{label} decode batch={batch}"), Some(batch as u64), || {
            if caches[0].len() >= cfg.max_seq {
                for c in caches.iter_mut() {
                    c.truncate(prompt_len);
                }
            }
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            black_box(decode_step(em, &mut refs, &toks, &mut state));
        });
    }
}

fn main() {
    let mut b = Bench::new("decode");
    let cfg = TransformerConfig::tiny_l();
    let model = Model::random(cfg, &mut Rng::new(6));
    let qm = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12());

    let packed = qm.to_exec();
    let dense = ExecModel::dense(&qm.to_dense());
    println!(
        "projection weights: packed {:.2} MB vs dense {:.2} MB",
        packed.projection_bytes() as f64 / 1e6,
        dense.projection_bytes() as f64 / 1e6
    );

    bench_backend(&mut b, &packed, "packed");
    bench_backend(&mut b, &dense, "dense");
    b.finish();
}
