//! Cross-module properties of the paged KV runtime (DESIGN.md §13).
//!
//! The load-bearing one is **page-refcount hygiene**: whatever the
//! serving history — pool sizes, page sizes, prefix-cache eviction
//! thrash, cold-page quantization, either admission policy — once every
//! request has retired and the prefix cache is drained, every f32 page
//! the pool ever allocated is back on its free list. `free == created`
//! simultaneously rules out leaks (a page some dropped table still
//! pinned) and double-frees (the same page on the free list twice would
//! overshoot `created`, and `KvPagePool::release` structurally cannot
//! re-admit a page with live readers). The satellite tests pin the
//! distinct-page residency census (shared pages counted once) and the
//! quantized-KV contract: lossy-but-tolerance-bounded logits with exact
//! byte accounting.

use claq::model::exec::{
    argmax, decode_step, prefill, ExecModel, ExecState, KvCache, KvPagePool, PageStat,
};
use claq::model::{Model, TransformerConfig};
use claq::runtime::scheduler::{AdmissionPolicy, Request, Scheduler, SchedulerConfig};
use claq::util::proptest::{check, Config};
use claq::util::rng::Rng;

fn test_config() -> TransformerConfig {
    TransformerConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

fn build_dense() -> ExecModel {
    ExecModel::dense(&Model::random(test_config(), &mut Rng::new(61)))
}

/// |a - b| ≤ tol element-wise (absolute; logits of the tiny test models
/// are O(1)).
fn assert_close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "element {i}: {x} vs {y} (tol {tol})");
    }
}

/// Every page the pool ever allocated comes home after the last retire +
/// prefix drain — across pool sizes (`max_slots` bounds the pre-warm),
/// page sizes, eviction thrash (tiny prefix budgets), quantization
/// on/off, and both admission policies.
#[test]
fn prop_every_page_returns_to_the_pool() {
    check("paged-KV refcount hygiene", Config { cases: 24, seed: 601 }, move |rng| {
        let model = build_dense();
        let model = &model;
        let cfg = model.config;
        let mut st = ExecState::new(cfg);

        let kv_page_tokens = 1 + rng.below_usize(8);
        let page_bytes = KvPagePool::with_page_tokens(cfg, kv_page_tokens).page_bytes();
        // 0 = off, tiny = insert/evict churn on nearly every retirement,
        // large = everything pins
        let prefix_cache_bytes = match rng.below_usize(3) {
            0 => 0,
            1 => 2 * page_bytes,
            _ => 1 << 20,
        };
        let sched_cfg = SchedulerConfig {
            max_slots: 1 + rng.below_usize(3),
            prefill_token_budget: 4 + rng.below_usize(12),
            policy: if rng.next_f64() < 0.5 {
                AdmissionPolicy::Continuous
            } else {
                AdmissionPolicy::Wave
            },
            prefix_cache_bytes,
            kv_page_tokens,
            // lossy cold-page re-encoding must not change who owns what
            kv_quant_bits: [0u8, 0, 3, 8][rng.below_usize(4)],
            kv_quant_margin: 2 + rng.below_usize(6),
        };
        let mut sched = Scheduler::new(cfg, sched_cfg);

        // shared-prefix-heavy staggered trace so shares, CoW forks,
        // pins, and evictions all actually happen
        let system: Vec<u16> =
            (0..4 + rng.below_usize(5)).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
        let n = 4 + rng.below_usize(5);
        let arrivals: Vec<(usize, Request)> = (0..n)
            .map(|i| {
                let mut prompt = if rng.next_f64() < 0.7 { system.clone() } else { Vec::new() };
                let tail = 1 + rng.below_usize(4);
                prompt.extend((0..tail).map(|_| rng.below(cfg.vocab as u64) as u16));
                let req = Request {
                    prompt,
                    max_new_tokens: 1 + rng.below_usize(5),
                    stop_token: None,
                };
                (rng.below_usize(4) * i, req)
            })
            .collect();

        let mut next = 0usize;
        let mut step = 0usize;
        let mut completed = 0usize;
        while next < arrivals.len() || sched.has_work() {
            while next < arrivals.len() && arrivals[next].0 <= step {
                sched.submit(arrivals[next].1.clone()).unwrap();
                next += 1;
            }
            if sched.has_work() {
                completed += sched.step(model, &mut st).len();
            }
            step += 1;
        }
        assert_eq!(completed, arrivals.len(), "every request must complete");

        sched.drain_prefix_cache();
        let stats = sched.stats();
        assert_eq!(
            stats.pool_free_pages as u64, stats.pool_pages_created,
            "page leak or double-free (stats: {stats:?})"
        );
        assert_eq!(stats.kv_pages_resident, 0, "no table may still reference pages");
        assert_eq!(stats.kv_resident_bytes, 0);
    });
}

/// The residency census counts each distinct page once, no matter how
/// many tables reference it — the fix for the pre-paging stats that
/// attributed a full forked cache to every request.
#[test]
fn resident_stats_count_shared_pages_once() {
    let model = build_dense();
    let mut st = ExecState::new(model.config);
    let mut sched = Scheduler::new(
        model.config,
        SchedulerConfig {
            max_slots: 2,
            prefix_cache_bytes: 1 << 20,
            kv_page_tokens: 4,
            ..SchedulerConfig::default()
        },
    );
    let page = KvPagePool::with_page_tokens(model.config, 4).page_bytes();
    let req = Request {
        prompt: vec![1, 2, 3, 4, 5, 6, 7, 8],
        max_new_tokens: 4,
        stop_token: None,
    };

    // first request retires and pins its 8-token prompt: 2 pages
    sched.submit(req.clone()).unwrap();
    assert_eq!(sched.run_to_completion(&model, &mut st).len(), 1);
    let pinned = sched.stats();
    assert_eq!(pinned.kv_pages_resident, 2);
    assert_eq!(pinned.kv_pages_shared, 0);
    assert_eq!(pinned.kv_resident_bytes, 2 * page);

    // an identical prompt admits sharing 7 positions out of the pinned
    // prefix: page 0 stays shared, the partial page 1 CoW-forks for the
    // 1-token tail prefill, and the same-step decode opens page 2.
    // Distinct pages: {page0, page1, page1', page2} = 4, NOT the 5 a
    // per-table sum (2 pinned + 3 live) would claim.
    sched.submit(req).unwrap();
    sched.step(&model, &mut st);
    let mid = sched.stats();
    assert_eq!(mid.kv_pages_resident, 4, "shared page must be counted once");
    assert_eq!(mid.kv_pages_shared, 1);
    assert_eq!(mid.kv_resident_bytes, 4 * page);
    assert_eq!(mid.prefix_hits, 1);
    let token_bytes = KvCache::new(&model.config).token_bytes() as u64;
    assert_eq!(mid.shared_kv_bytes_saved, 7 * token_bytes);

    // full drain: duplicate pin released, trie emptied, all pages home
    sched.run_to_completion(&model, &mut st);
    sched.drain_prefix_cache();
    let end = sched.stats();
    assert_eq!(end.pool_free_pages as u64, end.pool_pages_created);
    assert_eq!(end.kv_pages_resident, 0);
}

/// Quantized-KV reads are tolerance-gated, never bit-compared: decoding
/// over re-encoded cold pages stays within a small absolute band of the
/// exact-f32 logits (DESIGN.md §13 contract).
#[test]
fn quantized_kv_decode_stays_within_tolerance() {
    let model = build_dense();
    let cfg = model.config;
    let mut st = ExecState::new(cfg);
    let toks: Vec<u16> = (0..20).map(|i| (i * 7 % 31) as u16).collect();

    let mut exact = KvCache::with_page_tokens(&cfg, 4);
    let mut lossy = KvCache::with_page_tokens(&cfg, 4);
    let _ = prefill(&model, &mut exact, &toks, &mut st);
    let _ = prefill(&model, &mut lossy, &toks, &mut st);
    // margin 4 → cold_end 16 → pages 0..=3 re-encode
    assert_eq!(lossy.quantize_cold_pages(8, 4, None), 4);

    // several decode steps: appends go into fresh f32 pages while
    // attention keeps reading through the quantized ones
    let mut tok = 3u16;
    for _ in 0..4 {
        let a = decode_step(&model, &mut [&mut exact], &[tok], &mut st);
        let b = decode_step(&model, &mut [&mut lossy], &[tok], &mut st);
        assert_close(&a.data, &b.data, 0.05);
        // keep both caches on the *same* trajectory so the comparison
        // stays one-variable (the codec), even if argmax were to differ
        tok = argmax(a.row(0));
    }
    assert_eq!(exact.len(), lossy.len());
}

/// Byte accounting through the codec is exact: `bytes()` equals the
/// per-page sum, quantized pages are bounded by their u8-index + f32
/// codebook layout, and untouched pages still cost a full f32 page.
#[test]
fn quantized_page_byte_accounting_is_exact() {
    let model = build_dense();
    let cfg = model.config;
    let mut st = ExecState::new(cfg);
    let toks: Vec<u16> = (0..16).map(|i| (i * 3 % 31) as u16).collect();

    let mut c = KvCache::with_page_tokens(&cfg, 4);
    let _ = prefill(&model, &mut c, &toks, &mut st);
    let f32_bytes = c.bytes();
    assert_eq!(f32_bytes, 4 * c.page_bytes());

    // margin 4 → cold_end 12 → exactly pages 0..=2
    assert_eq!(c.quantize_cold_pages(8, 4, None), 3);
    let stats: Vec<PageStat> = c.page_stats().collect();
    assert_eq!(stats.iter().filter(|s| s.quantized).count(), 3);
    assert_eq!(c.bytes(), stats.iter().map(|s| s.bytes).sum::<usize>());
    assert!(c.bytes() < f32_bytes, "quantization must shrink residency");

    // per-page layout: n_layers × page_tokens × d u8 indices per tensor,
    // plus two ≤256-entry f32 codebooks
    let elems = 2 * cfg.n_layers * 4 * cfg.d_model; // K + V
    for s in &stats {
        if s.quantized {
            assert!(s.bytes >= elems, "indices alone cost {elems} bytes, got {}", s.bytes);
            assert!(
                s.bytes <= elems + 2 * 256 * 4,
                "codebooks are capped at 256 f32 entries each, got {}",
                s.bytes
            );
        } else {
            assert_eq!(s.bytes, c.page_bytes(), "f32 pages keep their full cost");
        }
    }
}
