//! Property suite for the vector-quantized plane kind (`CLAQVQ01`) end to
//! end — the sub-2-bit sibling of `tests/tiled_kernel.rs`. Sweeps group
//! dims × bit widths × outlier reservations × ragged shapes (group tails
//! narrower than `d`, column counts off the COL_TILE boundary) and checks:
//!
//! * tiled vs scalar gather kernels agree to tolerance over the fused
//!   grouped-gather decode, in memory and through the f16 container (with
//!   AWQ scales folded in);
//! * the bit-identity contract survives the plane-kind switch: batched
//!   output equals token-at-a-time output EXACTLY, including shapes that
//!   cross the parallel row-sharding threshold, for both kernels and for
//!   cold-loaded (container-parsed) operators alike — the accumulation
//!   order is a function of `(cols, group_dim)` alone;
//! * a `Method::ClaqVq` config actually lands under 2.0 container bits
//!   per parameter (codebooks and headers included) at serving shapes;
//! * at matched ~2.0 paper-equivalent bits, VQ reconstruction error is no
//!   worse than scalar CLAQ on matrices with correlated adjacent columns
//!   (the regime the plane kind exists for).

use claq::model::linear::{KernelKind, LinearOp, LinearScratch, PackedLinear};
use claq::quant::gptq::{quantize_matrix, MatrixPlan, QuantizedMatrix};
use claq::quant::packed::pack;
use claq::quant::vq::PlaneKind;
use claq::tensor::Matrix;
use claq::util::proptest::{check, gen_column, Config};
use claq::util::rng::Rng;

/// Random ragged-shaped VQ-quantized matrix: group dim 1..=6 (1 = the
/// degenerate scalar-like case), 2..=4 index bits, shapes chosen so the
/// final group is usually narrower than `d` and the in-group lane count
/// exercises both the axpy4 chunks and the axpy1 tail.
fn random_vq(rng: &mut Rng, with_outliers: bool) -> QuantizedMatrix {
    let rows = 3 + rng.below_usize(62); // 3..=64: crosses u64-window tails
    let cols = 1 + rng.below_usize(23); // 1..=23: ragged group tails
    let d = 1 + rng.below_usize(6); // 1..=6: straddles COL_TILE=4
    let bits = 2 + rng.below_usize(3) as u8; // 2..=4 bits per group index
    let mut w = Matrix::zeros(rows, cols);
    for c in 0..cols {
        let col = gen_column(rng, rows, 0.05);
        w.set_col(c, &col);
    }
    let mut plan = MatrixPlan::vector_group(cols, d, bits, true);
    if with_outliers {
        plan.reserve = (0..cols).map(|_| rng.below_usize(3)).collect();
    }
    quantize_matrix(&w, None, &plan)
}

fn forward(lin: &PackedLinear, x: &[f32], seq: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * lin.out_features()];
    let mut scratch = LinearScratch::new();
    lin.forward_into(x, seq, &mut out, &mut scratch);
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    for (a, b) in got.iter().zip(want) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "tiled {a} vs scalar {b} (tol {tol})");
    }
}

/// Tiled == scalar to tolerance over the fused grouped gather, with and
/// without reserved outliers, over random group dims and ragged shapes.
#[test]
fn prop_vq_tiled_matches_scalar_f32_codebooks() {
    for (seed, with_outliers) in [(701u64, false), (702, true)] {
        check("vq tiled vs scalar f32", Config { cases: 32, seed }, move |rng| {
            let qm = random_vq(rng, with_outliers);
            assert!(matches!(qm.plane_kind(), PlaneKind::VectorGroup { .. }));
            let scalar = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Scalar);
            let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);
            let seq = 1 + rng.below_usize(5);
            let mut x = vec![0.0f32; seq * qm.cols];
            rng.fill_normal(&mut x, 1.0);
            assert_close(&forward(&tiled, &x, seq), &forward(&scalar, &x, seq), 1e-5);
        });
    }
}

/// Same property through the serialized CLAQVQ01 container, so the group
/// codebooks both kernels gather from are f16-rounded — and with AWQ
/// scales folded into the decoded lanes.
#[test]
fn prop_vq_tiled_matches_scalar_f16_container_and_awq() {
    check("vq tiled vs scalar f16+awq", Config { cases: 24, seed: 703 }, |rng| {
        let qm = random_vq(rng, true);
        let scales: Vec<f32> = (0..qm.cols).map(|_| 0.5 + 1.5 * rng.next_f32()).collect();
        let (pm, rep) = pack(&qm).unwrap();
        assert!(matches!(rep.kind, PlaneKind::VectorGroup { .. }));
        let scalar = PackedLinear::from_container(&pm, Some(&scales))
            .unwrap()
            .with_kernel(KernelKind::Scalar);
        let tiled = PackedLinear::from_container(&pm, Some(&scales))
            .unwrap()
            .with_kernel(KernelKind::Tiled);
        let seq = 1 + rng.below_usize(4);
        let mut x = vec![0.0f32; seq * qm.cols];
        rng.fill_normal(&mut x, 1.0);
        assert_close(&forward(&tiled, &x, seq), &forward(&scalar, &x, seq), 1e-5);
    });
}

/// The bit-identity contract under VQ planes: batched output equals
/// token-at-a-time output EXACTLY (`assert_eq!`) for both kernels,
/// including shapes large enough to cross the parallel row-sharding
/// threshold, and including operators cold-loaded from the container —
/// per-element accumulation order is a function of `(cols, group_dim)`
/// alone, never of seq, shard count, codebook precision, or which
/// dispatch path ran.
#[test]
fn prop_vq_batched_and_sharded_bit_identical_to_serial() {
    check("vq bit identity", Config { cases: 10, seed: 704 }, |rng| {
        // big enough that seq·rows·cols crosses PAR_MIN_MACS on most draws
        let rows = 96 + rng.below_usize(96);
        let cols = 32 + rng.below_usize(64);
        let d = [2usize, 3, 4, 6][rng.below_usize(4)];
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::vector_group(cols, d, 3, true);
        plan.reserve = vec![1; cols];
        let qm = quantize_matrix(&w, None, &plan);
        let (pm, _) = pack(&qm).unwrap();

        let seq = 2 + rng.below_usize(7);
        let mut x = vec![0.0f32; seq * cols];
        rng.fill_normal(&mut x, 1.0);

        for kernel in [KernelKind::Tiled, KernelKind::Scalar] {
            let ops = [
                PackedLinear::from_quantized(&qm, None).with_kernel(kernel),
                PackedLinear::from_container(&pm, None).unwrap().with_kernel(kernel),
            ];
            for (which, lin) in ops.iter().enumerate() {
                // token-at-a-time reference (serial path: small MACs)
                let mut want = vec![0.0f32; seq * rows];
                let mut scratch = LinearScratch::new();
                for t in 0..seq {
                    let mut row_out = vec![0.0f32; rows];
                    lin.forward_into(&x[t * cols..(t + 1) * cols], 1, &mut row_out, &mut scratch);
                    want[t * rows..(t + 1) * rows].copy_from_slice(&row_out);
                }
                let got = forward(lin, &x, seq);
                assert_eq!(
                    got, want,
                    "vq batched/sharded diverged from serial \
                     ({rows}x{cols} d={d} {kernel:?} source={which})"
                );
                // and deterministic run over run
                assert_eq!(forward(lin, &x, seq), got);
            }
        }
    });
}

/// The headline budget claim, end to end at a serving-class shape: a
/// `ClaqVq { d: 4, bits: 2 }` quantization of a 256×128 matrix costs
/// under 2.0 container bits per parameter with *everything* counted —
/// packed index planes, f16 group codebooks, headers — at 0.5 paper
/// (index-only) bits, and the container cold-loads into a working
/// PackedLinear whose forward matches the dequantized reference.
#[test]
fn vq_sub_2bit_container_budget_end_to_end() {
    let mut rng = Rng::new(77);
    let (rows, cols) = (256usize, 128usize);
    let mut w = Matrix::zeros(rows, cols);
    rng.fill_normal(&mut w.data, 0.05);
    let plan = MatrixPlan::vector_group(cols, 4, 2, true);
    let qm = quantize_matrix(&w, None, &plan);
    let (pm, rep) = pack(&qm).unwrap();

    assert_eq!(rep.kind, PlaneKind::VectorGroup { d: 4 });
    assert!((rep.paper_equivalent_bits - 0.5).abs() < 1e-12, "index bits = 2/4 per param");
    let bpp = rep.container_bits_per_param();
    assert!(bpp < 2.0, "container bits/param {bpp} should be sub-2.0 at 256x128 d=4 2b");

    // cold-load and decode: container-parsed operator ≈ dequantized dense
    let lin = PackedLinear::from_container(&pm, None).unwrap().with_kernel(KernelKind::Tiled);
    let deq = claq::quant::packed::unpack(&pm).unwrap().dequantize();
    let mut x = vec![0.0f32; cols];
    rng.fill_normal(&mut x, 1.0);
    let got = forward(&lin, &x, 1);
    for r in 0..rows {
        let want: f32 = (0..cols).map(|c| deq.at(r, c) * x[c]).sum();
        assert!(
            (got[r] - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "row {r}: {} vs {want}",
            got[r]
        );
    }
}

/// Accuracy at a matched ~2.0 paper-bit budget: on matrices whose
/// adjacent column pairs are strongly correlated (the structure VQ
/// exploits), `d=2, bits=4` vector groups (16 centroids in R², 2.0
/// index bits/param) reconstruct no worse than scalar 2-bit CLAQ
/// (4 centroids per column, the same 2.0 index bits/param).
#[test]
fn vq_matches_scalar_accuracy_at_equal_paper_bits() {
    let mut rng = Rng::new(78);
    let (rows, cols) = (256usize, 16usize);
    let mut w = Matrix::zeros(rows, cols);
    for p in 0..cols / 2 {
        for r in 0..rows {
            let x = rng.next_f32() * 2.0 - 1.0;
            let eps = (rng.next_f32() - 0.5) * 0.05;
            *w.at_mut(r, 2 * p) = x;
            *w.at_mut(r, 2 * p + 1) = x + eps;
        }
    }

    let vq_plan = MatrixPlan::vector_group(cols, 2, 4, true);
    let sc_plan = MatrixPlan::uniform(cols, 2, claq::quant::gptq::CentroidRule::KMeans, true);
    let q_vq = quantize_matrix(&w, None, &vq_plan);
    let q_sc = quantize_matrix(&w, None, &sc_plan);

    // identical paper accounting on both sides: 2.0 bits, no outliers
    assert!((q_vq.equivalent_bits_paper() - 2.0).abs() < 1e-12);
    assert!((q_sc.equivalent_bits_paper() - 2.0).abs() < 1e-12);

    let (e_vq, e_sc) = (q_vq.metrics.rel_frobenius_err, q_sc.metrics.rel_frobenius_err);
    assert!(
        e_vq <= e_sc,
        "VQ rel-Frobenius {e_vq} should not lose to scalar {e_sc} on correlated pairs \
         at the same 2.0 paper bits"
    );
}
