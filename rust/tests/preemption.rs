//! Preemption / resume bit-identity (DESIGN.md §14).
//!
//! A preempted request re-queues with `prompt ++ generated` as its new
//! prompt, so resuming is a plain prefill over exactly the tokens its
//! cache held. Because the kernels are deterministic and batch-invariant
//! (`tests/scheduler.rs`), the prefill of position `p` writes the same KV
//! rows the original decode step wrote, and the logits at the last
//! position equal the decode logits the uninterrupted run saw — so the
//! resumed stream must be **bit-identical** to never having been
//! preempted. This suite preempts a request after *every* step of its
//! life (including a double preemption right after resume, which
//! exercises the prompt-rebuild path), across dense + packed backends ×
//! both admission policies × prefix cache off/on — with a pinned prefix
//! in play, the preempted cache shares pages with the trie and the
//! resume admission re-shares them, covering the CoW corners.

use claq::model::exec::{ExecModel, ExecState};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::scheduler::{
    AdmissionPolicy, Completion, Request, Scheduler, SchedulerConfig, SchedulerStats,
};
use claq::util::rng::Rng;

fn test_config() -> TransformerConfig {
    TransformerConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

fn build_dense() -> ExecModel {
    ExecModel::dense(&Model::random(test_config(), &mut Rng::new(81)))
}

fn build_packed() -> ExecModel {
    let model = Model::random(test_config(), &mut Rng::new(82));
    let em = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12()).to_exec();
    assert_eq!(em.backend, "packed");
    em
}

/// Serve request `a` to completion (seeding the prefix cache when it is
/// enabled), then serve `b`, preempting it after every engine step listed
/// in `preempt_after` (skipped when it already finished). Returns `b`'s
/// completion and the final stats.
fn serve_pair(
    model: &ExecModel,
    st: &mut ExecState,
    cfg: &SchedulerConfig,
    a: &Request,
    b: &Request,
    preempt_after: &[u64],
) -> (Completion, SchedulerStats) {
    let mut s = Scheduler::new(model.config, cfg.clone());
    s.submit(a.clone()).unwrap();
    // `steps` mirrors the scheduler's own step counter across both
    // phases, so `preempt_after` is in the same clock as
    // `Completion::finished_step`.
    let mut steps = 0u64;
    while s.has_work() {
        s.step(model, st);
        steps += 1;
    }
    let idb = s.submit(b.clone()).unwrap();
    let mut out = None;
    while s.has_work() {
        for c in s.step(model, st) {
            if c.id == idb {
                out = Some(c);
            }
        }
        steps += 1;
        if out.is_none() && preempt_after.contains(&steps) {
            assert!(s.preempt(idb), "request must be live after step {steps}");
        }
        assert!(steps < 1000, "preempted request failed to drain");
    }
    (out.expect("request b completed"), s.stats())
}

fn check_preemption_matrix(model: &ExecModel) {
    let mut st = ExecState::new(model.config);
    // b extends a's full prompt, so with the prefix cache enabled the
    // resume prefill lands on shared (pinned) pages.
    let a = Request { prompt: vec![3, 1, 4, 1], max_new_tokens: 5, stop_token: None };
    let b = Request { prompt: vec![3, 1, 4, 1, 5, 9], max_new_tokens: 8, stop_token: None };
    for policy in [AdmissionPolicy::Continuous, AdmissionPolicy::Wave] {
        for prefix_cache_bytes in [0usize, 1 << 20] {
            let cfg = SchedulerConfig {
                max_slots: 2,
                policy,
                prefix_cache_bytes,
                // 3-token pages: the request spans several pages, so
                // preemption and resume cross page boundaries and fork
                // partial tails
                kv_page_tokens: 3,
                ..SchedulerConfig::default()
            };
            let (base, base_stats) = serve_pair(model, &mut st, &cfg, &a, &b, &[]);
            assert_eq!(base_stats.preempted, 0);
            assert_eq!(base.tokens.len(), b.max_new_tokens);

            // b is live (preemptable) after every step from its
            // admission up to the one before it finishes
            for j in base.admitted_step..base.finished_step {
                for schedule in [vec![j], vec![j, j + 1]] {
                    let (got, stats) = serve_pair(model, &mut st, &cfg, &a, &b, &schedule);
                    assert_eq!(
                        got.tokens, base.tokens,
                        "preemption at {schedule:?} changed tokens \
                         (policy {policy:?}, prefix {prefix_cache_bytes})"
                    );
                    assert_eq!(got.reason, base.reason);
                    assert_eq!(got.prompt_len, b.prompt.len());
                    assert_eq!(
                        got.admitted_step, base.admitted_step,
                        "first-token step must survive preemption"
                    );
                    let expected = schedule
                        .iter()
                        .filter(|&&p| p >= got.admitted_step && p < got.finished_step)
                        .count() as u64;
                    assert_eq!(stats.preempted, expected);
                    assert_eq!(stats.resumed, expected);
                    assert_eq!(
                        stats.pool_free_pages as u64 + stats.kv_pages_resident as u64,
                        stats.pool_pages_created,
                        "live accounting must close (pinned prefixes are resident)"
                    );
                }
            }
        }
    }
}

#[test]
fn preempt_resume_is_bit_identical_dense() {
    let model = build_dense();
    check_preemption_matrix(&model);
}

#[test]
fn preempt_resume_is_bit_identical_packed() {
    let model = build_packed();
    check_preemption_matrix(&model);
}
