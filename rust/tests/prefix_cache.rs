//! Cross-module properties of prefix-sharing KV reuse (DESIGN.md §10).
//!
//! The load-bearing one is **cold/warm bit-identity**: with the prefix
//! cache enabled, every completion — tokens *and* stop reason — must be
//! identical to cold-prefill serving, for both execution backends and
//! both admission policies, on workloads engineered to hit the cache.
//! This holds because K/V rows of a position depend only on tokens at or
//! before it, every kernel is deterministic and batch/thread-invariant
//! (pinned since PR 2), and a prefix hit *shares* the very pages a cold
//! prefill would have recomputed bit-for-bit (no bytes are copied; only
//! a partial tail page is CoW-forked on first append). On top of
//! identity, the shared prefix must actually be *reused*:
//! `SchedulerStats` has to report prefix hits, saved prefill tokens, and
//! saved KV copy bytes on shared-prefix traces. Identity is exercised
//! across randomized KV page sizes — paging is memory granularity only.

use claq::model::exec::{argmax, decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::scheduler::{
    AdmissionPolicy, Request, Scheduler, SchedulerConfig, SchedulerStats,
};
use claq::util::proptest::{check, Config};
use claq::util::rng::Rng;
use std::collections::HashMap;

fn test_config() -> TransformerConfig {
    TransformerConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

fn build_dense() -> ExecModel {
    ExecModel::dense(&Model::random(test_config(), &mut Rng::new(81)))
}

fn build_packed() -> ExecModel {
    let model = Model::random(test_config(), &mut Rng::new(82));
    let em = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12()).to_exec();
    assert_eq!(em.backend, "packed");
    em
}

/// The single-request reference: prefill once, then one-row decode steps.
fn reference_generate(model: &ExecModel, st: &mut ExecState, req: &Request) -> Vec<u16> {
    let mut cache = KvCache::new(&model.config);
    let logits = prefill(model, &mut cache, &req.prompt, st);
    let mut toks = vec![argmax(logits.row(req.prompt.len() - 1))];
    while toks.len() < req.max_new_tokens && req.stop_token != Some(*toks.last().unwrap()) {
        let last = *toks.last().unwrap();
        let logits = decode_step(model, &mut [&mut cache], &[last], st);
        toks.push(argmax(logits.row(0)));
    }
    toks
}

/// Drive a scheduler over step-domain arrivals; returns completions by
/// request index (tokens + finish reason) and the final stats.
#[allow(clippy::type_complexity)]
fn staggered_serve(
    model: &ExecModel,
    st: &mut ExecState,
    cfg: SchedulerConfig,
    arrivals: &[(usize, Request)],
) -> (Vec<(Vec<u16>, claq::runtime::scheduler::FinishReason)>, SchedulerStats) {
    let mut sched = Scheduler::new(model.config, cfg);
    let mut ids = Vec::new();
    let mut by_id = HashMap::new();
    let mut next = 0usize;
    let mut step = 0usize;
    while next < arrivals.len() || sched.has_work() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            ids.push(sched.submit(arrivals[next].1.clone()).unwrap());
            next += 1;
        }
        if sched.has_work() {
            for c in sched.step(model, st) {
                by_id.insert(c.id, (c.tokens, c.reason));
            }
        }
        step += 1;
    }
    assert_eq!(by_id.len(), arrivals.len(), "every request must complete");
    let stats = sched.stats();
    (ids.iter().map(|id| by_id.remove(id).unwrap()).collect(), stats)
}

/// Shared-prefix arrivals: every prompt opens with the same system
/// prefix, and requests are staggered far enough apart that early
/// retirements can seed later admissions.
fn shared_prefix_arrivals(
    rng: &mut Rng,
    cfg: &TransformerConfig,
    n: usize,
    prefix_len: usize,
) -> Vec<(usize, Request)> {
    let system: Vec<u16> =
        (0..prefix_len).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
    (0..n)
        .map(|i| {
            let tail_len = 1 + rng.below_usize(4);
            let mut prompt = system.clone();
            prompt.extend((0..tail_len).map(|_| rng.below(cfg.vocab as u64) as u16));
            let max_new = 1 + rng.below_usize(5);
            let stop_token = if rng.next_f64() < 0.25 {
                Some(rng.below(cfg.vocab as u64) as u16)
            } else {
                None
            };
            // spacing > max_new guarantees at least some retire-then-admit
            // interleavings, i.e. real prefix hits
            (7 * i, Request { prompt, max_new_tokens: max_new, stop_token })
        })
        .collect()
}

/// Cold-prefill serving vs. prefix-cache serving vs. the single-request
/// reference: token streams and stop reasons must be identical, while the
/// cached run reports hits and saved tokens.
fn check_prefix_identity(build: fn() -> ExecModel, seed: u64, cases: usize) {
    check("prefix-cache cold/warm identity", Config { cases, seed }, move |rng| {
        let model = build();
        let model = &model;
        let cfg = model.config;
        let mut st = ExecState::new(cfg);
        let n = 3 + rng.below_usize(3);
        let prefix_len = 4 + rng.below_usize(5); // 4..=8 shared tokens
        let arrivals = shared_prefix_arrivals(rng, &cfg, n, prefix_len);
        let policy = if rng.next_f64() < 0.5 {
            AdmissionPolicy::Continuous
        } else {
            AdmissionPolicy::Wave
        };
        let sched_cfg = SchedulerConfig {
            max_slots: 1 + rng.below_usize(3),
            prefill_token_budget: 8 + rng.below_usize(12),
            policy,
            prefix_cache_bytes: 0,
            // shares land mid-page and on page boundaries alike
            kv_page_tokens: 1 + rng.below_usize(8),
            ..SchedulerConfig::default()
        };
        let (cold, cold_stats) = staggered_serve(model, &mut st, sched_cfg.clone(), &arrivals);
        let warm_cfg = SchedulerConfig { prefix_cache_bytes: 1 << 20, ..sched_cfg.clone() };
        let (warm, warm_stats) = staggered_serve(model, &mut st, warm_cfg, &arrivals);

        for (i, ((ct, cr), (wt, wr))) in cold.iter().zip(&warm).enumerate() {
            assert_eq!(ct, wt, "request {i} tokens diverged under {policy:?} with prefix cache");
            assert_eq!(cr, wr, "request {i} stop reason diverged under {policy:?}");
        }
        // the scheduler must also agree with N independent single-request
        // runs (transitively: cached serving == isolated serving)
        for (i, (_, req)) in arrivals.iter().enumerate() {
            let want = reference_generate(model, &mut st, req);
            assert_eq!(warm[i].0, want, "request {i} diverged from the isolated reference");
        }
        assert_eq!(cold_stats.prefix_lookups, 0);
        assert!(
            warm_stats.prefix_hits > 0,
            "shared-prefix workload produced no prefix hits (stats: {warm_stats:?})"
        );
        assert!(warm_stats.prefill_tokens_saved >= warm_stats.prefix_hits * prefix_len as u64);
        assert_eq!(
            warm_stats.prefill_tokens_in + warm_stats.prefill_tokens_saved,
            cold_stats.prefill_tokens_in,
            "every prompt token must be either prefilled or shared"
        );
        // page sharing saves exactly the KV bytes the pre-paging fork
        // memcpy'd: token_bytes per shared position, and zero when cold
        let tok_bytes = KvCache::new(&cfg).token_bytes() as u64;
        assert_eq!(cold_stats.shared_kv_bytes_saved, 0);
        assert_eq!(
            warm_stats.shared_kv_bytes_saved,
            warm_stats.prefill_tokens_saved * tok_bytes,
            "shared-KV byte accounting must match saved positions exactly"
        );
    });
}

/// Dense backend, both policies, randomized shared-prefix traces.
#[test]
fn prop_prefix_cache_identity_dense() {
    check_prefix_identity(build_dense, 501, 10);
}

/// Same property straight off the packed CLAQ planes (forked rows come
/// from the fused codebook-gather kernels).
#[test]
fn prop_prefix_cache_identity_packed() {
    check_prefix_identity(build_packed, 502, 5);
}

/// Eviction under a tiny byte budget must never corrupt results: with
/// room for a single pinned cache and many distinct prompts, the cache
/// thrashes (insert/evict every retirement) yet token streams stay
/// identical to cold serving.
#[test]
fn thrashing_prefix_cache_stays_bit_identical() {
    let model = build_dense();
    let cfg = model.config;
    let mut st = ExecState::new(cfg);
    // Budget for exactly one pinned prefix: caches are lazily paged now,
    // so the unit is a page (every 2..=6-token prompt below pins one
    // 32-token page), not a full contiguous cache.
    let one_cache = claq::model::exec::KvPagePool::new(cfg).page_bytes();
    let mut rng = Rng::new(907);
    // fully distinct prompts: every insert evicts the previous entry
    let arrivals: Vec<(usize, Request)> = (0..6)
        .map(|i| {
            let plen = 2 + rng.below_usize(5);
            let prompt: Vec<u16> =
                (0..plen).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
            (3 * i, Request { prompt, max_new_tokens: 1 + rng.below_usize(4), stop_token: None })
        })
        .collect();
    let base = SchedulerConfig { max_slots: 2, ..SchedulerConfig::default() };
    let (cold, _) = staggered_serve(&model, &mut st, base.clone(), &arrivals);
    let tiny = SchedulerConfig { prefix_cache_bytes: one_cache, ..base };
    let (warm, warm_stats) = staggered_serve(&model, &mut st, tiny, &arrivals);
    assert_eq!(cold, warm);
    assert!(warm_stats.prefix_evictions > 0, "budget for one cache must evict under churn");
    assert!(warm_stats.prefix_resident_bytes <= one_cache);
}

/// A request whose whole prompt is cached still prefills its final token
/// (the logits source): max reuse is prompt_len - 1, and repeating one
/// request is still bit-identical.
#[test]
fn identical_prompt_reuses_all_but_last_token() {
    let model = build_dense();
    let mut st = ExecState::new(model.config);
    let req = Request { prompt: vec![9, 8, 7, 6, 5], max_new_tokens: 4, stop_token: None };
    let want = reference_generate(&model, &mut st, &req);

    let mut sched = Scheduler::new(
        model.config,
        SchedulerConfig { prefix_cache_bytes: 1 << 20, ..SchedulerConfig::default() },
    );
    for _ in 0..3 {
        sched.submit(req.clone()).unwrap();
        let done = sched.run_to_completion(&model, &mut st);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, want);
    }
    let stats = sched.stats();
    assert_eq!(stats.prefix_hits, 2, "second and third submissions hit");
    assert_eq!(
        stats.prefill_tokens_saved,
        2 * (req.prompt.len() as u64 - 1),
        "reuse caps at prompt_len - 1 so the first token always has logits"
    );
    assert_eq!(stats.prefill_tokens_in, req.prompt.len() as u64 + 2);
}
