//! Integration: the PJRT runtime executing the AOT artifacts must agree
//! with the pure-Rust reference paths. Skips (with a notice) when
//! `artifacts/` has not been built yet (`make artifacts`).

use claq::data::corpus::{generate, CorpusKind};
use claq::model::forward::{forward, ForwardState};
use claq::model::io::load_model;
use claq::quant::kmeans::{kmeans_1d, KMeansOpts};
use claq::runtime::executor::{KMeansExecutor, ModelExecutor, QuantMatmulExecutor};
use claq::runtime::Runtime;
use claq::util::rng::Rng;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("model_l.hlo.txt").exists() && dir.join("weights_l.bin").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration test: run `make artifacts` first");
        None
    }
}

#[test]
fn pjrt_logits_match_rust_forward() {
    let Some(dir) = artifacts() else { return };
    let model = load_model(&dir.join("weights_l.bin")).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    let mut exec = ModelExecutor::new(dir.join("model_l.hlo.txt"), &model).unwrap();

    let stream = generate(CorpusKind::SynthC4, model.config.max_seq, 42);
    let mut state = ForwardState::new(model.config);
    let rust_logits = forward(&model, &stream, &mut state);
    let pjrt_logits = exec.logits(&mut rt, &stream).unwrap();

    assert_eq!(rust_logits.rows, pjrt_logits.rows);
    assert_eq!(rust_logits.cols, pjrt_logits.cols);
    let mut max_diff = 0.0f32;
    for (a, b) in rust_logits.data.iter().zip(&pjrt_logits.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(
        max_diff < 2e-2,
        "Rust forward and PJRT graph disagree: max |diff| = {max_diff}"
    );
}

#[test]
fn pjrt_perplexity_close_to_rust_eval() {
    let Some(dir) = artifacts() else { return };
    let model = load_model(&dir.join("weights_l.bin")).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    let mut exec = ModelExecutor::new(dir.join("model_l.hlo.txt"), &model).unwrap();
    let stream = generate(CorpusKind::SynthC4, model.config.max_seq * 4, 7);
    let pjrt_ppl = exec.perplexity(&mut rt, &stream, 0).unwrap();
    let rust_ppl = claq::eval::perplexity::perplexity(&model, &stream, 0).ppl;
    assert!(
        (pjrt_ppl / rust_ppl - 1.0).abs() < 0.02,
        "PPL mismatch: pjrt {pjrt_ppl} vs rust {rust_ppl}"
    );
}

#[test]
fn quant_matmul_kernel_matches_rust_dequant() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("quant_matmul.hlo.txt");
    if !path.exists() {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    let exec = QuantMatmulExecutor::standard(path);
    let (m, k, n, levels) = (exec.m, exec.k, exec.n, exec.levels);

    let mut rng = Rng::new(1);
    let mut x = vec![0.0f32; m * k];
    rng.fill_normal(&mut x, 1.0);
    let mut codebooks = vec![0.0f32; k * levels];
    rng.fill_normal(&mut codebooks, 0.1);
    let indices: Vec<i32> = (0..n * k).map(|_| rng.below(levels as u64) as i32).collect();

    let y = exec.run(&mut rt, &x, &codebooks, &indices).unwrap();

    // Rust reference: dequant + matmul
    let mut yref = vec![0.0f32; m * n];
    for i in 0..m {
        for o in 0..n {
            let mut acc = 0.0f32;
            for j in 0..k {
                let w = codebooks[j * levels + indices[o * k + j] as usize];
                acc += x[i * k + j] * w;
            }
            yref[i * n + o] = acc;
        }
    }
    let mut max_diff = 0.0f32;
    for (a, b) in y.iter().zip(&yref) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "fused dequant-matmul mismatch: {max_diff}");
}

#[test]
fn kmeans_kernel_step_reduces_rust_inertia() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("kmeans_step.hlo.txt");
    if !path.exists() {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    let exec = KMeansExecutor::standard(path);
    let (c, n, k) = (exec.c, exec.n, exec.k);

    let mut rng = Rng::new(2);
    let mut values = vec![0.0f32; c * n];
    rng.fill_normal(&mut values, 1.0);
    let mut centroids = vec![0.0f32; c * k];
    rng.fill_normal(&mut centroids, 1.0);

    let (_, inertia0) = exec.step(&mut rt, &values, &centroids).unwrap();
    let (c1, _) = exec.step(&mut rt, &values, &centroids).unwrap();
    let (_, inertia1) = exec.step(&mut rt, &values, &c1).unwrap();
    let s0: f64 = inertia0.iter().map(|&x| x as f64).sum();
    let s1: f64 = inertia1.iter().map(|&x| x as f64).sum();
    assert!(s1 <= s0 + 1e-3, "Lloyd step increased inertia {s0} -> {s1}");

    // And the final Rust Lloyd solution is at least as good as one PJRT step
    // on the first column.
    let col: Vec<f32> = values[..n].to_vec();
    let rust = kmeans_1d(&col, k, &KMeansOpts::default());
    let rust_inertia = claq::quant::kmeans::inertia(&col, &rust.codebook);
    assert!(rust_inertia <= s1, "converged Lloyd worse than a single step?");
}
