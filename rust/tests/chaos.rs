//! Deterministic chaos suite for the overload-hardened serving runtime
//! (DESIGN.md §14).
//!
//! Each case drives a randomized schedule of submissions, cancellations,
//! and per-request step deadlines through a scheduler whose page pool is
//! squeezed two ways at once: a hard byte budget small enough to force
//! the degradation ladder (prefix eviction → forced cold-page
//! quantization → preemption → rejection), and a seeded `pool_take`
//! failpoint that makes takes fail even when memory is available. The
//! invariants checked are exactly the robustness contract:
//!
//! 1. **Total accounting** — every submitted request resolves with
//!    exactly one completion, and the per-reason counters sum to the
//!    submission count.
//! 2. **Page hygiene** — after the system drains (plus a prefix-cache
//!    drain), `pool_free_pages == pool_pages_created` and the
//!    distinct-page census is zero: no leak, no double-free, under any
//!    injected failure schedule.
//! 3. **Survivor bit-identity** — with KV quantization off, every
//!    request that finishes `Length`/`Stop` (including preempted-and-
//!    resumed ones) matches its single-request fault-free reference
//!    token-for-token, and every `Cancelled`/`DeadlineExceeded` partial
//!    output is a prefix of that reference.
//!
//! The suite is also wired to the env failpoint path: CI runs it with
//! `CLAQ_FAILPOINTS=pool_take@p0.05;seed=7` so env-armed pools are
//! exercised too; the in-test schedulers install their own (or empty)
//! failpoint sets, which replace the env-derived one deterministically.

use claq::model::checkpoint::Checkpoint;
use claq::model::exec::{argmax, decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::scheduler::{
    AdmissionPolicy, Completion, FinishReason, Request, Scheduler, SchedulerConfig,
};
use claq::util::failpoint::{self, Failpoints};
use claq::util::proptest::{check, Config};
use claq::util::rng::Rng;
use claq::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::Arc;

fn test_config() -> TransformerConfig {
    TransformerConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

fn build_dense() -> ExecModel {
    ExecModel::dense(&Model::random(test_config(), &mut Rng::new(91)))
}

fn build_packed() -> ExecModel {
    let model = Model::random(test_config(), &mut Rng::new(92));
    let em = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12()).to_exec();
    assert_eq!(em.backend, "packed");
    em
}

/// The fault-free single-request reference (same as `tests/scheduler.rs`).
fn reference_generate(model: &ExecModel, st: &mut ExecState, req: &Request) -> Vec<u16> {
    let mut cache = KvCache::new(&model.config);
    let logits = prefill(model, &mut cache, &req.prompt, st);
    let mut toks = vec![argmax(logits.row(req.prompt.len() - 1))];
    while toks.len() < req.max_new_tokens && req.stop_token != Some(*toks.last().unwrap()) {
        let last = *toks.last().unwrap();
        let logits = decode_step(model, &mut [&mut cache], &[last], st);
        toks.push(argmax(logits.row(0)));
    }
    toks
}

/// One planned request of a chaos schedule.
struct Planned {
    req: Request,
    arrive_step: u64,
    /// Step deadline passed to `submit_with_deadline` (0 = none).
    deadline: u64,
    /// Engine step at which `cancel` is called (if still unresolved).
    cancel_step: Option<u64>,
}

fn random_plan(rng: &mut Rng, vocab: usize, n: usize) -> Vec<Planned> {
    let mut plan: Vec<Planned> = (0..n)
        .map(|_| {
            let plen = 1 + rng.below_usize(6);
            let prompt: Vec<u16> = (0..plen).map(|_| rng.below(vocab as u64) as u16).collect();
            let max_new = 1 + rng.below_usize(6);
            let stop_token =
                if rng.next_f64() < 0.33 { Some(rng.below(vocab as u64) as u16) } else { None };
            let arrive_step = rng.below(8);
            Planned {
                req: Request { prompt, max_new_tokens: max_new, stop_token },
                arrive_step,
                deadline: if rng.next_f64() < 0.25 { 2 + rng.below(10) } else { 0 },
                cancel_step: (rng.next_f64() < 0.2).then(|| arrive_step + 1 + rng.below(8)),
            }
        })
        .collect();
    plan.sort_by_key(|p| p.arrive_step);
    plan
}

/// Drive one chaos case end to end and check the three invariants.
fn run_chaos_case(model: &ExecModel, st: &mut ExecState, rng: &mut Rng, quant: bool) {
    let cfg = model.config;
    let n = 3 + rng.below_usize(5);
    let plan = random_plan(rng, cfg.vocab, n);

    let page_tokens = 1 + rng.below_usize(8);
    let page_bytes = 2 * cfg.n_layers * page_tokens * cfg.d_model * std::mem::size_of::<f32>();
    // 60% of cases: a budget of 2..=7 pages — tight enough at these
    // request sizes to force every ladder rung, including rejections.
    let budget_pages = if rng.next_f64() < 0.6 { 2 + rng.below_usize(6) } else { 0 };
    let sched_cfg = SchedulerConfig {
        max_slots: 1 + rng.below_usize(3),
        prefill_token_budget: 4 + rng.below_usize(12),
        policy: if rng.next_f64() < 0.5 { AdmissionPolicy::Continuous } else { AdmissionPolicy::Wave },
        prefix_cache_bytes: if rng.next_f64() < 0.5 { 0 } else { 1 << 20 },
        kv_page_tokens: page_tokens,
        kv_quant_bits: if quant { 8 } else { 0 },
        kv_quant_margin: rng.below_usize(8),
        kv_budget_bytes: budget_pages * page_bytes,
        max_queue: if rng.next_f64() < 0.3 { 1 + rng.below_usize(4) } else { 0 },
        ..SchedulerConfig::default()
    };
    let mut s = Scheduler::new(cfg, sched_cfg);
    // Seeded injected faults on top of (replacing) any env-armed set:
    // the schedule is a pure function of this seed, so failures replay.
    let p = 0.05 + rng.next_f64() * 0.15;
    s.set_failpoints(Arc::new(Failpoints::new(rng.below(1 << 30)).with_point(failpoint::POOL_TAKE, p)));

    let mut ids: Vec<Option<u64>> = (0..n).map(|_| None).collect();
    let mut completions: HashMap<u64, Completion> = HashMap::new();
    let mut next = 0usize;
    let mut step = 0u64;
    while next < n || s.has_work() {
        while next < n && plan[next].arrive_step <= step {
            ids[next] = Some(s.submit_with_deadline(plan[next].req.clone(), plan[next].deadline).unwrap());
            next += 1;
        }
        for (i, planned) in plan.iter().enumerate() {
            if planned.cancel_step == Some(step) {
                if let Some(id) = ids[i] {
                    if let Some(c) = s.cancel(id) {
                        completions.insert(c.id, c);
                    }
                }
            }
        }
        if s.has_work() {
            for c in s.step(model, st) {
                completions.insert(c.id, c);
            }
        }
        step += 1;
        assert!(step < 10_000, "chaos schedule failed to drain");
    }

    // 1. Total accounting: one completion per submission, counters close.
    assert_eq!(completions.len(), n, "every request must resolve exactly once");
    let stats = s.stats();
    assert_eq!(
        stats.completed + stats.cancelled + stats.deadline_exceeded + stats.rejected,
        n as u64,
        "per-reason counters must cover every submission: {stats:?}"
    );
    // Not equality: a preempted request can be cancelled or expire
    // while re-queued, resolving without ever resuming.
    assert!(stats.resumed <= stats.preempted, "resumed without a preemption: {stats:?}");

    // 3. Survivor bit-identity (lossless configs only: quantized KV is
    // tolerance-gated, never bit-compared).
    if !quant {
        for (i, planned) in plan.iter().enumerate() {
            let c = &completions[&ids[i].expect("all submitted")];
            match c.reason {
                FinishReason::Length | FinishReason::Stop => {
                    let want = reference_generate(model, st, &planned.req);
                    assert_eq!(
                        c.tokens, want,
                        "request {i} diverged from its fault-free reference"
                    );
                }
                FinishReason::Cancelled | FinishReason::DeadlineExceeded => {
                    let want = reference_generate(model, st, &planned.req);
                    assert!(
                        want.starts_with(&c.tokens),
                        "request {i}: partial output {:?} is not a prefix of {:?}",
                        c.tokens,
                        want
                    );
                }
                FinishReason::Rejected => {
                    assert!(c.tokens.is_empty());
                    assert_eq!(c.admitted_step, 0);
                }
            }
        }
    }

    // 2. Page hygiene after full drain.
    s.drain_prefix_cache();
    let stats = s.stats();
    assert_eq!(
        stats.pool_free_pages as u64, stats.pool_pages_created,
        "page leak or double-free under injected faults: {stats:?}"
    );
    assert_eq!(stats.kv_pages_resident, 0);
}

/// `build` is a fn pointer so the property closure stays `RefUnwindSafe`
/// (same idiom as `tests/scheduler.rs`).
fn check_chaos(build: fn() -> ExecModel, seed: u64, cases: usize) {
    check("scheduler chaos", Config { cases, seed }, move |rng| {
        let model = build();
        let mut st = ExecState::new(model.config);
        let quant = rng.next_f64() < 0.3;
        run_chaos_case(&model, &mut st, rng, quant);
    });
}

#[test]
fn prop_chaos_dense() {
    check_chaos(build_dense, 501, 16);
}

#[test]
fn prop_chaos_packed() {
    check_chaos(build_packed, 502, 8);
}

/// A scheduler with no budget and an explicitly *empty* failpoint set
/// behaves exactly like the pre-overload engine — fault-free serving
/// reports no overload activity at all (the "all existing bit-identity
/// suites pass unchanged" half of the acceptance contract, checked from
/// inside this suite even when CI arms `CLAQ_FAILPOINTS` for it).
#[test]
fn unarmed_serving_reports_no_overload_activity() {
    let model = build_dense();
    let mut st = ExecState::new(model.config);
    let mut s = Scheduler::new(model.config, SchedulerConfig::default());
    s.set_failpoints(Arc::new(Failpoints::new(0)));
    for i in 0..4u16 {
        s.submit(Request { prompt: vec![i + 1, i + 2], max_new_tokens: 4, stop_token: None })
            .unwrap();
    }
    let done = s.run_to_completion(&model, &mut st);
    assert_eq!(done.len(), 4);
    assert!(done.iter().all(|c| c.reason.is_success()));
    let stats = s.stats();
    assert_eq!(
        (stats.rejected, stats.cancelled, stats.deadline_exceeded, stats.preempted, stats.resumed),
        (0, 0, 0, 0, 0)
    );
    assert_eq!(stats.pool_failed_takes, 0);
}

/// The ladder's first rung is observable: under a tight budget with a
/// warm prefix cache, admission evicts pinned prefixes before touching
/// live requests.
#[test]
fn pressure_evicts_pinned_prefixes_first() {
    let model = build_dense();
    let mut st = ExecState::new(model.config);
    let page_bytes = 2 * model.config.n_layers * 4 * model.config.d_model * 4;
    let mut s = Scheduler::new(
        model.config,
        SchedulerConfig {
            max_slots: 1,
            kv_page_tokens: 4,
            kv_budget_bytes: 4 * page_bytes,
            prefix_cache_bytes: 1 << 20,
            ..SchedulerConfig::default()
        },
    );
    s.set_failpoints(Arc::new(Failpoints::new(0)));
    // Fill the budget with pinned prefixes, then serve a request that
    // needs the pages back.
    for i in 0..3u16 {
        s.submit(Request {
            prompt: vec![i + 1, i + 2, i + 3, i + 4, i + 5],
            max_new_tokens: 2,
            stop_token: None,
        })
        .unwrap();
        s.run_to_completion(&model, &mut st);
    }
    assert!(s.stats().prefix_entries >= 2, "prefixes must be pinned: {:?}", s.stats());
    s.submit(Request { prompt: vec![9; 10], max_new_tokens: 6, stop_token: None }).unwrap();
    let done = s.run_to_completion(&model, &mut st);
    assert!(done.iter().all(|c| c.reason.is_success()));
    let stats = s.stats();
    assert!(stats.prefix_evictions > 0, "rung 1 never fired: {stats:?}");
    assert_eq!(stats.preempted, 0, "eviction must satisfy pressure before preemption");
    s.drain_prefix_cache();
    let stats = s.stats();
    assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
}

/// Rung 2: with quantization enabled, pressure force-quantizes cold
/// pages (margin 0) before preempting. Two requests that each fit the
/// budget alone — so neither is shed up front — but not together: the
/// shortfall must come out of cold pages, not a preemption.
#[test]
fn pressure_forces_cold_page_quantization_when_enabled() {
    let model = build_dense();
    let mut st = ExecState::new(model.config);
    let page_bytes = 2 * model.config.n_layers * 2 * model.config.d_model * 4;
    let mut s = Scheduler::new(
        model.config,
        SchedulerConfig {
            max_slots: 2,
            kv_page_tokens: 2,
            // each request spans 4 two-token pages (2 prompt + 6
            // generated) — within the 4-page budget alone, 8 pages
            // together: the second half of each stream runs past what
            // f32 residency allows
            kv_budget_bytes: 4 * page_bytes,
            kv_quant_bits: 8,
            // huge margin: the periodic post-step sweep never fires, so
            // any quantized page is the pressure path's doing
            kv_quant_margin: 1 << 20,
            ..SchedulerConfig::default()
        },
    );
    s.set_failpoints(Arc::new(Failpoints::new(0)));
    s.submit(Request { prompt: vec![5, 6], max_new_tokens: 6, stop_token: None }).unwrap();
    s.submit(Request { prompt: vec![7, 8], max_new_tokens: 6, stop_token: None }).unwrap();
    let done = s.run_to_completion(&model, &mut st);
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|c| c.reason == FinishReason::Length && c.tokens.len() == 6));
    let stats = s.stats();
    assert!(stats.kv_pages_quantized_total > 0, "rung 2 never fired: {stats:?}");
    assert_eq!(stats.preempted, 0, "quantization must satisfy pressure before preemption");
    assert_eq!(stats.pool_free_pages as u64, stats.pool_pages_created);
}

/// An injected `ckpt_decode` fault surfaces as a structured decode error
/// (the cold-start error path), and disarms with its scope.
#[test]
fn checkpoint_decode_failpoint_is_scoped_and_structured() {
    let model = Model::random(test_config(), &mut Rng::new(93));
    let qm = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12());
    let bytes = Checkpoint::from_quantized(&qm).unwrap().encode().unwrap();
    assert!(Checkpoint::decode(&bytes).is_ok(), "sane checkpoint decodes");
    {
        let _guard = failpoint::scoped(Arc::new(
            Failpoints::new(7).with_point(failpoint::CKPT_DECODE, 1.0),
        ));
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(
            format!("{err:#}").contains(failpoint::CKPT_DECODE),
            "error must name the failpoint: {err:#}"
        );
    }
    assert!(Checkpoint::decode(&bytes).is_ok(), "failpoint disarms with its scope");
}

/// A panic on the global pool (the one the sharded kernels dispatch on)
/// must not poison it: the same packed forward pass is bit-identical
/// before and after — the serving half of the thread-pool panic
/// isolation contract (`util/threadpool.rs` has the pool-level half).
#[test]
fn global_pool_panic_leaves_packed_forwards_bit_identical() {
    let model = build_packed();
    let mut st = ExecState::new(model.config);
    let prompt = [1u16, 2, 3, 4, 5, 6];
    let mut cache = KvCache::new(&model.config);
    let before = prefill(&model, &mut cache, &prompt, &mut st);

    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ThreadPool::global().run_units(32, |i| {
            if i == 5 {
                panic!("injected job panic");
            }
        });
    }));
    // With CLAQ_THREADS=1 the pool runs inline and the panic still
    // propagates; either way it must not poison later dispatches.
    assert!(result.is_err(), "the panic payload must surface");

    let mut cache = KvCache::new(&model.config);
    let after = prefill(&model, &mut cache, &prompt, &mut st);
    assert_eq!(before.data, after.data, "pool panic changed kernel results");
}
