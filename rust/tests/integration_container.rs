//! Integration: quantized-model containers round-trip through disk and
//! reconstruct the same dense weights.

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::model::checkpoint::Checkpoint;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::quant::packed::{load, pack, save, unpack};
use claq::util::rng::Rng;

#[test]
fn quantized_model_survives_disk_round_trip() {
    let cfg = TransformerConfig {
        vocab: VOCAB,
        d_model: 24,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    };
    let model = Model::random(cfg, &mut Rng::new(5));
    let stream = generate(CorpusKind::SynthWiki, 8_000, 1);
    let calib = sample_segments(&stream, &CalibConfig { n_segments: 6, seq_len: 32, seed: 1 });
    let (qm, _) = quantize_model(&model, &Method::fusion_2_12(), &calib, &PipelineOpts::default());

    let dir = claq::util::tmp::unique_path("container_it");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // Every packed matrix survives a standalone container file round trip
    // and reloads to identical dequantized weights modulo the f16 codebook
    // storage.
    for (&id, qmat) in &qm.matrices {
        let path = dir.join(format!("{}.claq", id.name()));
        let (pm, _) = pack(qmat).unwrap();
        save(&pm, &path).unwrap();
        let pm_back = load(&path).unwrap();
        assert_eq!(pm.bytes, pm_back.bytes, "{}: container bytes changed on disk", id.name());
        let back = unpack(&pm_back).unwrap();
        let a = qmat.dequantize();
        let b = back.dequantize();
        let mut max_rel = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            let denom = x.abs().max(1e-3) as f64;
            max_rel = max_rel.max(((x - y).abs() as f64) / denom);
        }
        assert!(max_rel < 1.0 / 512.0, "{}: f16 codebook error too large {max_rel}", id.name());
        // and the bytes round-trip exactly through a re-pack
        let (pm2, _) = pack(&back).unwrap();
        assert_eq!(pm.bytes, pm2.bytes);
    }

    // the single-file checkpoint carries the same set of matrices
    let ckpt_path = dir.join("model.claqmd");
    qm.save(&ckpt_path).unwrap();
    let ckpt = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ckpt.entries.len(), qm.matrices.len());
    assert_eq!(ckpt.method_name, qm.method_name);
    let _ = std::fs::remove_dir_all(&dir);
}
