//! Cross-module properties of the continuous-batching serving runtime.
//!
//! The load-bearing one is **batch invariance**: whatever mix of requests
//! the scheduler packs into a fused decode step — staggered arrivals,
//! mid-stream backfill, wave drains — every request's greedy token stream
//! must be identical to running that request alone through
//! `prefill` + one-row `decode_step`. This holds because (a) each decode
//! row only attends to its own cache, (b) the sharded kernels accumulate
//! every output element in the same ascending-column order regardless of
//! batch shape or thread count, and (c) `argmax` tie-breaks
//! deterministically. It is what makes serving results reproducible and
//! lets the bench compare policies by throughput alone. The property is
//! also exercised with the prefix-sharing KV cache enabled (short random
//! prompts collide often, so page shares really fire) and under
//! randomized KV page sizes (paging is pure memory granularity, so the
//! page size must be invisible to every token stream);
//! shared-prefix-specific properties live in `tests/prefix_cache.rs`,
//! page-refcount hygiene in `tests/paged_kv.rs`.

use claq::model::exec::{
    argmax, decode_step, prefill, ExecModel, ExecState, KvCache, KvPagePool,
};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::runtime::scheduler::{AdmissionPolicy, Request, Scheduler, SchedulerConfig};
use claq::util::proptest::{check, Config};
use claq::util::rng::Rng;
use std::collections::HashMap;

fn test_config() -> TransformerConfig {
    TransformerConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

/// The single-request reference: prefill once, then one-row decode steps.
fn reference_generate(model: &ExecModel, st: &mut ExecState, req: &Request) -> Vec<u16> {
    let mut cache = KvCache::new(&model.config);
    let logits = prefill(model, &mut cache, &req.prompt, st);
    let mut toks = vec![argmax(logits.row(req.prompt.len() - 1))];
    while toks.len() < req.max_new_tokens && req.stop_token != Some(*toks.last().unwrap()) {
        let last = *toks.last().unwrap();
        let logits = decode_step(model, &mut [&mut cache], &[last], st);
        toks.push(argmax(logits.row(0)));
    }
    toks
}

/// Drive a scheduler over step-domain arrivals: request `i` is submitted
/// just before engine step `arrivals[i].0`. Returns tokens by request
/// index.
fn staggered_serve(
    model: &ExecModel,
    st: &mut ExecState,
    cfg: SchedulerConfig,
    arrivals: &[(usize, Request)],
) -> Vec<Vec<u16>> {
    let mut sched = Scheduler::new(model.config, cfg);
    let mut ids = Vec::new();
    let mut tokens_by_id: HashMap<u64, Vec<u16>> = HashMap::new();
    let mut next = 0usize;
    let mut step = 0usize;
    while next < arrivals.len() || sched.has_work() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            ids.push(sched.submit(arrivals[next].1.clone()).unwrap());
            next += 1;
        }
        if sched.has_work() {
            for c in sched.step(model, st) {
                tokens_by_id.insert(c.id, c.tokens);
            }
        }
        step += 1;
    }
    assert_eq!(tokens_by_id.len(), arrivals.len(), "every request must complete");
    ids.iter().map(|id| tokens_by_id.remove(id).unwrap()).collect()
}

fn random_arrivals(rng: &mut Rng, cfg: &TransformerConfig, n: usize) -> Vec<(usize, Request)> {
    let mut arrivals: Vec<(usize, Request)> = (0..n)
        .map(|_| {
            let plen = 1 + rng.below_usize(6);
            let prompt: Vec<u16> = (0..plen).map(|_| rng.below(cfg.vocab as u64) as u16).collect();
            let max_new = 1 + rng.below_usize(6);
            let stop_token = if rng.next_f64() < 0.33 {
                Some(rng.below(cfg.vocab as u64) as u16)
            } else {
                None
            };
            (rng.below_usize(6), Request { prompt, max_new_tokens: max_new, stop_token })
        })
        .collect();
    arrivals.sort_by_key(|(step, _)| *step);
    arrivals
}

/// `build` is a fn pointer (not a capture) so the property closure stays
/// `RefUnwindSafe`; the test models are small enough to rebuild per case.
fn check_batch_invariance(build: fn() -> ExecModel, seed: u64, cases: usize) {
    check("scheduler batch invariance", Config { cases, seed }, move |rng| {
        let model = build();
        let model = &model;
        let cfg = model.config;
        let mut st = ExecState::new(cfg);
        let n = 2 + rng.below_usize(4);
        let arrivals = random_arrivals(rng, &cfg, n);
        let sched_cfg = SchedulerConfig {
            max_slots: 1 + rng.below_usize(3),
            prefill_token_budget: 4 + rng.below_usize(12),
            policy: if rng.next_f64() < 0.5 {
                AdmissionPolicy::Continuous
            } else {
                AdmissionPolicy::Wave
            },
            // half the cases serve through the prefix cache; 1..=6-token
            // prompts over a 32-token vocab collide often enough that
            // shared admissions really happen
            prefix_cache_bytes: if rng.next_f64() < 0.5 { 0 } else { 1 << 20 },
            // 1..=8-token pages against max_seq 32: most requests span
            // several pages, partial-tail CoW forks fire, and the token
            // streams must not notice
            kv_page_tokens: 1 + rng.below_usize(8),
            ..SchedulerConfig::default()
        };
        let served = staggered_serve(model, &mut st, sched_cfg.clone(), &arrivals);
        for (i, (_, req)) in arrivals.iter().enumerate() {
            let want = reference_generate(model, &mut st, req);
            assert_eq!(
                served[i], want,
                "request {i} diverged under {:?} (prompt {:?})",
                sched_cfg.policy, req.prompt
            );
        }
    });
}

fn build_dense() -> ExecModel {
    ExecModel::dense(&Model::random(test_config(), &mut Rng::new(71)))
}

fn build_packed() -> ExecModel {
    let model = Model::random(test_config(), &mut Rng::new(72));
    let em = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12()).to_exec();
    assert_eq!(em.backend, "packed");
    em
}

/// N staggered requests through the scheduler are token-identical to N
/// independent single-request runs — dense backend, both policies.
#[test]
fn prop_scheduler_matches_single_request_dense() {
    check_batch_invariance(build_dense, 301, 12);
}

/// Same property straight off the packed CLAQ planes (exercises the
/// thread-sharded fused codebook-gather kernel under mixed batches).
#[test]
fn prop_scheduler_matches_single_request_packed() {
    check_batch_invariance(build_packed, 302, 6);
}

/// A recycled pool cache behaves exactly like a fresh one, including
/// truncate-replay, and the pool accounts for its resident pages.
/// Recycled pages are deliberately *not* zeroed — positions ≥ `len` are
/// never read, and this test reuses a dirty page to prove it.
#[test]
fn pool_reuse_preserves_cache_semantics() {
    let cfg = test_config();
    let model = Model::random(cfg, &mut Rng::new(73));
    let em = ExecModel::dense(&model);
    let mut st = ExecState::new(cfg);
    let mut pool = KvPagePool::with_capacity(cfg, 1);
    let page = pool.page_bytes();
    assert_eq!(pool.resident_bytes(), page, "one prewarmed request = one 32-token page here");

    // use a cache, return it, take it back: must start empty
    let mut c = pool.take_cache();
    assert!(c.is_empty());
    c.reserve(&mut pool, 4);
    assert_eq!(pool.resident_bytes(), 0, "reserved pages leave the pool");
    let full = prefill(&em, &mut c, &[1, 2, 3, 4], &mut st);
    pool.put_cache(c);
    assert_eq!(pool.resident_bytes(), page);

    // recycled (dirty) cache behaves exactly like a fresh one
    let mut c = pool.take_cache();
    assert!(c.is_empty());
    c.reserve(&mut pool, 4);
    let again = prefill(&em, &mut c, &[1, 2, 3, 4], &mut st);
    assert_eq!(again.data, full.data);

    // recycled cache supports prefix truncation exactly like a fresh one
    c.truncate(2);
    let replay = prefill(&em, &mut c, &[3, 4], &mut st);
    assert_eq!(replay.row(1), full.row(3));
    assert_eq!(c.len(), 4);
    pool.put_cache(c);

    assert_eq!((pool.hits(), pool.misses()), (2, 0), "both reserves hit the prewarmed page");
    assert!((pool.hit_rate() - 1.0).abs() < 1e-12);
    assert_eq!(pool.pages_created(), 1);
    assert_eq!(pool.free_pages(), 1, "full drain returns the page");
}
