//! End-to-end property of the single-file CLAQMD01 checkpoint: quantize
//! (AWQ scales, outlier reservation, mixed per-column `BitPlan` bits) →
//! checkpoint → load → `ExecModel`, asserting **bit-identical** logits
//! against the in-memory deployed packed path
//! (`QuantizedModel::to_exec_deployed`, which routes every projection
//! through the same `CLAQPK01` codec). Thread-count and batch-composition
//! invariance of the kernels is pinned separately
//! (`model/linear.rs::sharded_forward_is_bit_identical_to_serial`,
//! `tests/scheduler.rs`); on top of it, this test varies the batch shape
//! (prefill, decode at batch 1 and 3) so the equality holds on every
//! dispatch path a server exercises.
//!
//! Also: checkpoint files must be strictly smaller than `save_model` of
//! the FP model, and corrupt/truncated/trailing-byte files must be
//! rejected (mirroring `corrupt_containers_rejected`).

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::model::checkpoint::Checkpoint;
use claq::model::exec::{argmax, decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::io::save_model;
use claq::model::quantized::QuantizedModel;
use claq::model::{MatrixId, MatrixKind, Model, TransformerConfig};
use claq::quant::config::Method;
use claq::quant::gptq::{quantize_matrix, MatrixPlan};
use claq::util::rng::Rng;

fn test_cfg() -> TransformerConfig {
    TransformerConfig {
        vocab: VOCAB,
        d_model: 24,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    }
}

fn quantized(method: &Method) -> (Model, QuantizedModel) {
    let model = Model::random(test_cfg(), &mut Rng::new(23));
    let stream = generate(CorpusKind::SynthC4, 4000, 1);
    let calib = sample_segments(&stream, &CalibConfig { n_segments: 6, seq_len: 32, seed: 4 });
    let (qm, _) = quantize_model(&model, method, &calib, &PipelineOpts::default());
    (model, qm)
}

fn uniq_path(tag: &str) -> std::path::PathBuf {
    claq::util::tmp::unique_path(&format!("rt_{tag}"))
}

fn assert_bits_equal(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: shape");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: logit {i}: {x} vs {y}");
    }
}

/// Full prefill + greedy decode comparison between two exec models: every
/// logit bit-identical, hence every greedy token identical.
fn assert_exec_bit_identical(a: &ExecModel, b: &ExecModel, ctx: &str) {
    let cfg = a.config;
    let mut st = ExecState::new(cfg);
    let toks: Vec<u16> = (0..16u16).map(|i| (i * 37) % VOCAB as u16).collect();

    let mut ca = KvCache::new(&cfg);
    let mut cb = KvCache::new(&cfg);
    let la = prefill(a, &mut ca, &toks, &mut st);
    let lb = prefill(b, &mut cb, &toks, &mut st);
    assert_bits_equal(&la.data, &lb.data, &format!("{ctx}: prefill"));

    // greedy decode, batch 1: token streams must not diverge
    let mut ta = argmax(la.row(toks.len() - 1));
    let mut tb = argmax(lb.row(toks.len() - 1));
    for step in 0..6 {
        assert_eq!(ta, tb, "{ctx}: greedy token diverged at step {step}");
        let la = decode_step(a, &mut [&mut ca], &[ta], &mut st);
        let lb = decode_step(b, &mut [&mut cb], &[tb], &mut st);
        assert_bits_equal(&la.data, &lb.data, &format!("{ctx}: decode step {step}"));
        ta = argmax(la.row(0));
        tb = argmax(lb.row(0));
    }

    // batch-3 decode (mixed depths) goes down the batched dispatch path
    let prompts: [&[u16]; 3] = [&[1, 2, 3], &[9, 8, 7, 6, 5], &[40, 0]];
    let mk = |m: &ExecModel, st: &mut ExecState| -> Vec<KvCache> {
        prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&cfg);
                let _ = prefill(m, &mut c, p, st);
                c
            })
            .collect()
    };
    let mut caches_a = mk(a, &mut st);
    let mut caches_b = mk(b, &mut st);
    let next = [4u16, 11, 200];
    let mut refs_a: Vec<&mut KvCache> = caches_a.iter_mut().collect();
    let mut refs_b: Vec<&mut KvCache> = caches_b.iter_mut().collect();
    let la = decode_step(a, &mut refs_a, &next, &mut st);
    let lb = decode_step(b, &mut refs_b, &next, &mut st);
    assert_bits_equal(&la.data, &lb.data, &format!("{ctx}: batch-3 decode"));
}

fn round_trip(method: &Method, tag: &str) {
    let (fp_model, qm) = quantized(method);
    let ctx = qm.method_name.clone();

    // save → strictly smaller than the FP artifact
    let ckpt_path = uniq_path(tag);
    let written = qm.save(&ckpt_path).unwrap();
    let fp_path = uniq_path(&format!("{tag}_fp"));
    save_model(&fp_model, &fp_path).unwrap();
    let fp_len = std::fs::metadata(&fp_path).unwrap().len();
    assert!(
        written < fp_len,
        "{ctx}: checkpoint ({written} B) must be smaller than the FP artifact ({fp_len} B)"
    );
    assert_eq!(written, qm.size_report().checkpoint_bytes as u64, "{ctx}: exact accounting");

    // load → cold-start exec must be bit-identical to the in-memory
    // deployed path (both sides see f16 container codebooks)
    let ckpt = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ckpt.method_name, qm.method_name);
    let cold = ExecModel::from_checkpoint(ckpt).unwrap();
    assert_eq!(cold.backend, "packed");
    let deployed = qm.to_exec_deployed().unwrap();
    assert_exec_bit_identical(&cold, &deployed, &format!("{ctx}: cold vs deployed"));

    // the QuantizedModel::load inverse path serves identically as well
    let loaded = QuantizedModel::load(&ckpt_path).unwrap();
    assert_eq!(loaded.method_name, qm.method_name);
    assert_eq!(loaded.awq_scales.len(), qm.awq_scales.len());
    assert_exec_bit_identical(&loaded.to_exec(), &cold, &format!("{ctx}: loaded vs cold"));

    let _ = std::fs::remove_file(&ckpt_path);
    let _ = std::fs::remove_file(&fp_path);
}

/// CLAQ*-2.12: adaptive precision (mixed per-column bits) + outlier
/// reservation — the paper's headline low-bit configuration.
#[test]
fn fusion_checkpoint_round_trip_bit_identical() {
    round_trip(&Method::fusion_2_12(), "fusion");
}

/// AWQ: per-column activation scales must survive the file and fold into
/// the cold-started kernels exactly (the bug that motivated this format —
/// the old save_dir dropped them).
#[test]
fn awq_checkpoint_round_trip_bit_identical() {
    round_trip(&Method::Awq { bits: 4 }, "awq");
}

/// Plain CLAQ at 3 bits (uniform plan, no scales, no reservation).
#[test]
fn claq3_checkpoint_round_trip_bit_identical() {
    round_trip(&Method::Claq { bits: 3 }, "claq3");
}

/// Vector-quantized planes (CLAQVQ01 containers): 2-bit indices over
/// 4-wide column groups — 0.5 index bits/param, the sub-2-bit
/// configuration the plane-kind refactor exists for. The whole
/// quantize → save → cold-load → decode path must hold bit-identity
/// exactly as for scalar planes.
#[test]
fn vq_checkpoint_round_trip_bit_identical() {
    round_trip(&Method::ClaqVq { d: 4, bits: 2 }, "vq");
}

/// One CLAQMD01 file mixing plane kinds: a scalar-quantized model with a
/// single projection swapped to a vector-quantized plane. Per-entry
/// container magic dispatch must round-trip the mix, and the size report
/// must partition the byte budget by kind.
#[test]
fn mixed_plane_kind_checkpoint_round_trip() {
    let (_, mut qm) = quantized(&Method::Claq { bits: 2 });
    let id = MatrixId { layer: 0, kind: MatrixKind::WUp };
    let w = qm.base.matrix(id).clone();
    let plan = MatrixPlan::vector_group(w.cols, 4, 2, true);
    qm.matrices.insert(id, quantize_matrix(&w, None, &plan));

    let path = uniq_path("mixed");
    let written = qm.save(&path).unwrap();
    assert_eq!(
        written,
        qm.size_report().checkpoint_bytes as u64,
        "mixed: exact accounting"
    );
    let rep = qm.size_report();
    assert_eq!(rep.vq_matrices, 1, "exactly the swapped projection is VQ");
    assert_eq!(rep.scalar_matrices, qm.matrices.len() - 1);
    assert_eq!(
        rep.scalar_container_bytes + rep.vq_container_bytes,
        rep.container_bytes,
        "per-kind bytes partition the container budget"
    );

    let ckpt = Checkpoint::load(&path).unwrap();
    let n_vq = ckpt
        .entries
        .iter()
        .filter(|e| e.container.bytes.starts_with(b"CLAQVQ01"))
        .count();
    let n_scalar = ckpt
        .entries
        .iter()
        .filter(|e| e.container.bytes.starts_with(b"CLAQPK01"))
        .count();
    assert_eq!(n_vq, 1, "one embedded CLAQVQ01 container");
    assert_eq!(n_vq + n_scalar, ckpt.entries.len(), "every entry is one of the two kinds");

    let cold = ExecModel::from_checkpoint(ckpt).unwrap();
    let deployed = qm.to_exec_deployed().unwrap();
    assert_exec_bit_identical(&cold, &deployed, "mixed: cold vs deployed");

    let loaded = QuantizedModel::load(&path).unwrap();
    assert_exec_bit_identical(&loaded.to_exec(), &cold, "mixed: loaded vs cold");

    let _ = std::fs::remove_file(&path);
}

/// Corruption inside an embedded CLAQVQ01 container. The checkpoint
/// header scan (`Checkpoint::load`) validates container magic + dims;
/// deeper plane corruption is caught where the container is actually
/// parsed (`ExecModel::from_checkpoint` / `QuantizedModel::load`).
#[test]
fn corrupt_vq_containers_in_checkpoint_rejected() {
    let (_, qm) = quantized(&Method::ClaqVq { d: 4, bits: 2 });
    let path = uniq_path("vq_corrupt");
    qm.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let pos = bytes
        .windows(8)
        .position(|w| w == b"CLAQVQ01")
        .expect("checkpoint embeds a CLAQVQ01 container");

    // flipped magic byte on the embedded container -> rejected at load
    let mut bad = bytes.clone();
    bad[pos] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    assert!(Checkpoint::load(&path).is_err(), "bad VQ container magic accepted");

    // zeroed group-dim header byte (offset 20 = after magic/rows/cols/n_out):
    // passes the cheap header scan, must fail at container parse time
    let mut bad = bytes.clone();
    bad[pos + 20] = 0;
    std::fs::write(&path, &bad).unwrap();
    let ckpt = Checkpoint::load(&path).expect("header scan does not parse planes");
    assert!(ExecModel::from_checkpoint(ckpt).is_err(), "group dim 0 accepted by exec build");
    assert!(QuantizedModel::load(&path).is_err(), "group dim 0 accepted by model load");

    // inflated group dim: the declared group count / codebook extents no
    // longer match the container byte stream (truncated-codebook shape)
    let mut bad = bytes.clone();
    bad[pos + 20] = 255;
    std::fs::write(&path, &bad).unwrap();
    let ckpt = Checkpoint::load(&path).expect("header scan does not parse planes");
    assert!(
        ExecModel::from_checkpoint(ckpt).is_err(),
        "group-dim/cols mismatch accepted by exec build"
    );

    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_checkpoint_files_rejected() {
    let (_, qm) = quantized(&Method::Claq { bits: 2 });
    let path = uniq_path("corrupt");
    qm.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    assert!(Checkpoint::load(&path).is_err(), "bad magic accepted");

    // truncated mid-entry
    std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
    assert!(Checkpoint::load(&path).is_err(), "truncated file accepted");

    // trailing garbage
    let mut long = bytes.clone();
    long.extend_from_slice(b"xx");
    std::fs::write(&path, &long).unwrap();
    assert!(Checkpoint::load(&path).is_err(), "trailing bytes accepted");

    // an entry claiming an out-of-range matrix kind
    // (entries start right after the FP block; corrupt the first kind tag)
    let ok = Checkpoint::decode(&bytes).unwrap();
    let mut evil = ok.clone();
    evil.entries.swap(0, 1); // order is not part of the contract...
    assert!(Checkpoint::decode(&evil.encode().unwrap()).is_ok());
    evil.entries[0].id.layer = 999; // ...but out-of-range layers are
    assert!(Checkpoint::decode(&evil.encode().unwrap()).is_err());

    let _ = std::fs::remove_file(&path);
}
