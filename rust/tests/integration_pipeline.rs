//! Integration: the full quantization pipeline on a trained-or-random
//! model — the paper's qualitative orderings must hold end to end.

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::eval::perplexity::perplexity;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::util::rng::Rng;

fn test_model() -> Model {
    let cfg = TransformerConfig {
        vocab: VOCAB,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 48,
        max_seq: 64,
        rope_theta: 10000.0,
        eps: 1e-5,
    };
    Model::random(cfg, &mut Rng::new(99))
}

struct Setup {
    model: Model,
    calib: Vec<Vec<u16>>,
    heldout: Vec<u16>,
}

fn setup() -> Setup {
    let model = test_model();
    let stream = generate(CorpusKind::SynthC4, 20_000, 1);
    let calib = sample_segments(&stream, &CalibConfig { n_segments: 12, seq_len: 64, seed: 3 });
    let heldout = generate(CorpusKind::SynthC4, 64 * 12, 2);
    Setup { model, calib, heldout }
}

fn ppl_of(s: &Setup, method: Method) -> f64 {
    let (qm, _) = quantize_model(&s.model, &method, &s.calib, &PipelineOpts::default());
    perplexity(&qm.to_dense(), &s.heldout, 0).ppl
}

/// Table 1's qualitative shape at 4 bits: every 4-bit method stays close
/// to FP16, and CLAQ's weight-space error is smallest.
#[test]
fn four_bit_methods_close_to_fp16() {
    let s = setup();
    let fp = perplexity(&s.model, &s.heldout, 0).ppl;
    for method in [Method::Rtn { bits: 4 }, Method::Gptq { bits: 4 }, Method::Claq { bits: 4 }] {
        let p = ppl_of(&s, method.clone());
        assert!(
            (p / fp - 1.0).abs() < 0.25,
            "{}: ppl {p} too far from fp16 {fp}",
            method.name()
        );
    }
}

/// The 2-bit story: CLAQ-2 must reconstruct the weights dramatically
/// better than GPTQ-2 (paper Table 1's mechanism). On a *random* test
/// model 2-bit PPL is saturated noise, so the assertion is on the
/// deterministic reconstruction error; the PPL ordering on the *trained*
/// model is reproduced by `claq table 1` (see DESIGN.md §5).
#[test]
fn two_bit_claq_beats_gptq() {
    let s = setup();
    let (gptq2, _) =
        quantize_model(&s.model, &Method::Gptq { bits: 2 }, &s.calib, &PipelineOpts::default());
    let (claq2, _) =
        quantize_model(&s.model, &Method::Claq { bits: 2 }, &s.calib, &PipelineOpts::default());
    assert!(
        claq2.mean_rel_err() < gptq2.mean_rel_err() * 0.85,
        "CLAQ-2 ({}) should clearly beat GPTQ-2 ({})",
        claq2.mean_rel_err(),
        gptq2.mean_rel_err()
    );
    // (No PPL sub-assertion here: an untrained random model sits at the
    // uniform-PPL noise floor where 2-bit quantization can move either
    // way. The trained-model PPL collapse is verified by `claq table 1`.)
}

/// Fusion (AP+OR) recovers reconstruction quality over plain CLAQ-2
/// (Table 1 CLAQ*-2.12/2.24 mechanism) — deterministic error metric for
/// the same reason as above.
#[test]
fn fusion_recovers_two_bit() {
    let s = setup();
    let (claq2, _) =
        quantize_model(&s.model, &Method::Claq { bits: 2 }, &s.calib, &PipelineOpts::default());
    let (fusion, _) =
        quantize_model(&s.model, &Method::fusion_2_24(), &s.calib, &PipelineOpts::default());
    assert!(
        fusion.mean_rel_err() < claq2.mean_rel_err(),
        "CLAQ*-2.24 ({}) should improve on CLAQ-2 ({})",
        fusion.mean_rel_err(),
        claq2.mean_rel_err()
    );
}

/// Per-matrix quantization error ordering: K-Means codebooks beat uniform
/// at equal bits across the whole model (the §3.1 claim).
#[test]
fn kmeans_weight_error_beats_uniform_end_to_end() {
    let s = setup();
    let (claq, _) = quantize_model(&s.model, &Method::Claq { bits: 3 }, &s.calib, &PipelineOpts::default());
    let (gptq, _) = quantize_model(&s.model, &Method::Gptq { bits: 3 }, &s.calib, &PipelineOpts::default());
    assert!(claq.mean_rel_err() < gptq.mean_rel_err());
}

/// Size accounting: fusion presets land near their nominal bit budgets.
#[test]
fn fusion_size_accounting() {
    let s = setup();
    let (qm, _) = quantize_model(&s.model, &Method::fusion_2_12(), &s.calib, &PipelineOpts::default());
    let rep = qm.size_report();
    assert!(
        (rep.paper_equivalent_bits - 2.12).abs() < 0.06,
        "equivalent bits {} vs nominal 2.12",
        rep.paper_equivalent_bits
    );
    // honest container accounting is strictly larger (codebooks + coords)
    assert!(rep.container_bits_per_param > rep.paper_equivalent_bits);
}
