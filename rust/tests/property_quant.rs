//! Cross-module property tests: invariants that must hold across the
//! quantization stack for randomized inputs (mini-proptest harness from
//! `util::proptest`, deterministic seeds, failures replay).

use claq::quant::codebook::{uniform_codebook, Codebook};
use claq::quant::config::Method;
use claq::quant::gptq::{
    quantize_matrix, quantize_matrix_pooled, CentroidRule, MatrixPlan, QuantScratch,
    QuantizedMatrix,
};
use claq::quant::kmeans::{inertia, kmeans_1d, KMeansOpts};
use claq::quant::outliers::OutlierStats;
use claq::quant::packed::{pack, unpack};
use claq::quant::precision::{allocate_ap, BitPair};
use claq::quant::reservation::{allocate_or, OrSetting};
use claq::tensor::Matrix;
use claq::util::proptest::{check, gen_column, Config};
use claq::util::rng::Rng;

fn random_matrix(rng: &mut Rng, max_rows: usize, max_cols: usize) -> Matrix {
    let rows = 4 + rng.below_usize(max_rows);
    let cols = 2 + rng.below_usize(max_cols);
    let mut w = Matrix::zeros(rows, cols);
    for c in 0..cols {
        let col = gen_column(rng, rows, 0.03);
        w.set_col(c, &col);
    }
    w
}

/// K-Means codebooks never do worse than uniform codebooks on inertia
/// (the §3.1 claim, as an invariant over random columns).
#[test]
fn prop_kmeans_inertia_le_uniform() {
    check("kmeans <= uniform inertia", Config { cases: 64, seed: 101 }, |rng| {
        let n = 32 + rng.below_usize(256);
        let col = gen_column(rng, n, 0.02);
        let bits = 2 + rng.below_usize(3) as u32;
        let km = kmeans_1d(&col, 1 << bits, &KMeansOpts::default());
        let uni = uniform_codebook(&col, 1 << bits);
        let (e_km, e_uni) = (inertia(&col, &km.codebook), inertia(&col, &uni));
        assert!(
            e_km <= e_uni * 1.001 + 1e-12,
            "kmeans {e_km} worse than uniform {e_uni}"
        );
    });
}

/// Quantize→dequantize→quantize is a fixed point (idempotence).
#[test]
fn prop_quantization_idempotent() {
    check("idempotent", Config { cases: 48, seed: 102 }, |rng| {
        let col = gen_column(rng, 64, 0.02);
        let cb = kmeans_1d(&col, 8, &KMeansOpts::default()).codebook;
        for &x in col.iter().take(16) {
            let q1 = cb.dequantize(cb.quantize(x));
            let q2 = cb.dequantize(cb.quantize(q1));
            assert_eq!(q1, q2);
        }
    });
}

/// Container round-trip preserves indices, bits, and outliers exactly for
/// arbitrary mixed-precision + reservation plans.
#[test]
fn prop_container_round_trip() {
    check("container round trip", Config { cases: 24, seed: 103 }, |rng| {
        let w = random_matrix(rng, 48, 24);
        let mut plan = MatrixPlan::uniform(w.cols, 2, CentroidRule::KMeans, false);
        for c in 0..w.cols {
            plan.bits[c] = [2u8, 3, 4][rng.below_usize(3)];
        }
        plan.reserve = (0..w.cols).map(|_| rng.below_usize(4) * 2).collect();
        let q = quantize_matrix(&w, None, &plan);
        let (pm, report) = pack(&q).unwrap();
        assert_eq!(pm.bytes.len(), report.container_bytes());
        let back = unpack(&pm).unwrap();
        assert_eq!(back.outliers, q.outliers);
        for (a, b) in back.columns().iter().zip(q.columns()) {
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.bits, b.bits);
        }
    });
}

/// The AP allocator hits the bit budget within one column of rounding for
/// any score distribution, and promotes a superset-of-none of the lowest
/// scores (never promotes a column while a strictly higher-scored column
/// stays low — monotonicity).
#[test]
fn prop_ap_monotone_in_scores() {
    check("ap monotone", Config { cases: 64, seed: 104 }, |rng| {
        let n = 8 + rng.below_usize(128);
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let pair = BitPair::new(4, 2);
        let target = 2.0 + rng.next_f64() * 2.0;
        let plan = allocate_ap(&scores, pair, target);
        let min_promoted = plan
            .promoted
            .iter()
            .map(|&c| scores[c])
            .fold(f64::INFINITY, f64::min);
        for (c, &s) in scores.iter().enumerate() {
            if !plan.promoted.contains(&c) {
                assert!(
                    s <= min_promoted + 1e-12,
                    "unpromoted column {c} outscores a promoted one"
                );
            }
        }
    });
}

/// OR budgets are never exceeded and counts are always even and bounded.
#[test]
fn prop_or_budget_never_exceeded() {
    check("or budget", Config { cases: 48, seed: 105 }, |rng| {
        let w = random_matrix(rng, 128, 48);
        let stats = OutlierStats::compute(&w, 1.0 + rng.next_f64() * 12.0);
        let budget = rng.next_f64() * 0.4;
        let plan = allocate_or(&stats, w.rows, budget, OrSetting::by_id(1 + rng.below_usize(3)));
        assert!(plan.overhead_bits <= budget + 1e-9);
        for &c in &plan.counts {
            assert_eq!(c % 2, 0);
            assert!(c <= w.rows);
        }
    });
}

/// Error compensation (OBS) never *increases* the calibration-weighted
/// output error versus no compensation, across random SPD Hessians.
#[test]
fn prop_obs_no_worse_output_error() {
    check("obs helps", Config { cases: 12, seed: 106 }, |rng| {
        let w = random_matrix(rng, 40, 16);
        let cols = w.cols;
        let mut x = Matrix::zeros(3 * cols, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let h = claq::tensor::linalg::gram(&x, 1e-6);
        let out_err = |deq: &Matrix| -> f64 {
            let mut total = 0.0;
            for r in 0..w.rows {
                for i in 0..cols {
                    let di = (w.at(r, i) - deq.at(r, i)) as f64;
                    if di == 0.0 {
                        continue;
                    }
                    for j in 0..cols {
                        total += di * h[i * cols + j] * (w.at(r, j) - deq.at(r, j)) as f64;
                    }
                }
            }
            total
        };
        let plan_off = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
        let plan_on = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, true);
        let e_off = out_err(&quantize_matrix(&w, None, &plan_off).dequantize());
        let e_on = out_err(&quantize_matrix(&w, Some(&h), &plan_on).dequantize());
        // Allow slack: OBS is greedy, not globally optimal, but should win
        // clearly on average; we assert it never loses catastrophically.
        assert!(
            e_on <= e_off * 1.25,
            "OBS output error {e_on} ≫ plain {e_off}"
        );
    });
}

/// The tentpole invariant of the blocked quantizer: for dense random W and
/// real (gram) Hessians, every block size and every thread count produces
/// output bit-identical to the unblocked serial path — indices, codebooks,
/// outliers, dequantized weights, and metrics alike — for both centroid
/// rules, with and without outlier reservations.
#[test]
fn prop_blocked_quantizer_bit_identical() {
    fn assert_bit_identical(a: &QuantizedMatrix, b: &QuantizedMatrix, ctx: &str) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
        for (c, (ca, cb)) in a.columns().iter().zip(b.columns()).enumerate() {
            assert_eq!(ca.bits, cb.bits, "{ctx}: bits col {c}");
            assert_eq!(ca.indices, cb.indices, "{ctx}: indices col {c}");
            let bits_a: Vec<u32> = ca.codebook.centroids.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = cb.codebook.centroids.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{ctx}: codebook col {c}");
        }
        assert_eq!(a.outliers, b.outliers, "{ctx}: outliers");
        let (da, db) = (a.dequantize(), b.dequantize());
        for (i, (x, y)) in da.data.iter().zip(&db.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: weight elem {i}");
        }
        assert_eq!(
            a.metrics.rel_frobenius_err.to_bits(),
            b.metrics.rel_frobenius_err.to_bits(),
            "{ctx}: rel_frobenius_err"
        );
        assert_eq!(
            a.metrics.proxy_loss.to_bits(),
            b.metrics.proxy_loss.to_bits(),
            "{ctx}: proxy_loss"
        );
    }

    check("blocked == unblocked", Config { cases: 8, seed: 108 }, |rng| {
        // Mostly small shapes for breadth; ~1 in 4 cases grows rows past
        // the quantizer's parallel-dispatch gates (64Ki MACs, 8 rows per
        // shard), so the sharded trailing kernel is exercised with real
        // Hessians, K-Means, and reservations — not just the serial path.
        let tall = if rng.next_f64() < 0.25 { 600 } else { 0 };
        let rows = 16 + tall + rng.below_usize(48);
        let cols = 8 + rng.below_usize(24);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.02);
        let mut x = Matrix::zeros(2 * cols, cols);
        rng.fill_normal(&mut x.data, 1.0);
        let mut h = claq::tensor::linalg::gram(&x, 0.0);
        for v in h.iter_mut() {
            *v *= 2.0;
        }
        let pools = [
            claq::util::threadpool::ThreadPool::new(1),
            claq::util::threadpool::ThreadPool::new(2),
            claq::util::threadpool::ThreadPool::new(5),
        ];
        for rule in [CentroidRule::KMeans, CentroidRule::UniformMinMax] {
            for reserve in [0usize, 2] {
                let mut plan = MatrixPlan::uniform(cols, 2, rule, true);
                if reserve > 0 {
                    plan.reserve = (0..cols).map(|c| (c % 3) * reserve).collect();
                }
                plan.block_size = 0; // unblocked serial reference
                let reference = quantize_matrix(&w, Some(&h), &plan);
                for bs in [1usize, 7, 64, cols] {
                    plan.block_size = bs;
                    for pool in &pools {
                        let q = quantize_matrix_pooled(
                            &w,
                            Some(&h),
                            &plan,
                            pool,
                            &mut QuantScratch::new(),
                        );
                        let ctx = format!(
                            "{rows}x{cols} {rule:?} reserve={reserve} B={bs} threads={}",
                            pool.workers()
                        );
                        assert_bit_identical(&reference, &q, &ctx);
                    }
                }
            }
        }
    });
}

/// Method::nominal_bits is consistent with what the pipeline achieves for
/// single-precision methods on random matrices.
#[test]
fn prop_nominal_bits_consistent() {
    check("nominal bits", Config { cases: 24, seed: 107 }, |rng| {
        let w = random_matrix(rng, 64, 32);
        let bits = 2 + rng.below_usize(3) as u8;
        let m = Method::Claq { bits };
        let plan = m.plan_for(&w, None).unwrap();
        let q = quantize_matrix(&w, None, &plan);
        assert!((q.equivalent_bits_paper() - m.nominal_bits()).abs() < 1e-9);
    });
}
