//! Cross-module properties of the packed execution backend: the fused
//! codebook-gather kernel must agree with dequantize-then-dense-matmul for
//! arbitrary bit maps, outlier reservations, and AWQ scales, and the f16
//! container codec must honor IEEE 754 binary16 edge cases.

use claq::model::linear::{LinearOp, LinearScratch, PackedLinear};
use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan, QuantizedMatrix};
use claq::quant::packed::{f16_bits_to_f32, f32_to_f16_bits, pack};
use claq::tensor::Matrix;
use claq::util::proptest::{check, gen_column, Config};
use claq::util::rng::Rng;

fn random_quantized(rng: &mut Rng, with_outliers: bool) -> (Matrix, QuantizedMatrix) {
    let rows = 4 + rng.below_usize(36);
    let cols = 2 + rng.below_usize(18);
    let mut w = Matrix::zeros(rows, cols);
    for c in 0..cols {
        let col = gen_column(rng, rows, 0.03);
        w.set_col(c, &col);
    }
    let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
    for c in 0..cols {
        plan.bits[c] = 2 + rng.below_usize(7) as u8; // 2..=8 bits
    }
    if with_outliers {
        plan.reserve = (0..cols).map(|_| rng.below_usize(4)).collect();
    }
    let qm = quantize_matrix(&w, None, &plan);
    (w, qm)
}

fn dense_forward(deq: &Matrix, x: &[f32], seq: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * deq.rows];
    let mut scratch = LinearScratch::new();
    deq.forward_into(x, seq, &mut out, &mut scratch);
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    for (a, b) in got.iter().zip(want) {
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "packed {a} vs dense {b} (tol {tol})"
        );
    }
}

/// PackedLinear output matches dequantize()-then-dense matmul to tight
/// tolerance across random bit maps (2–8 bits), with and without outliers.
#[test]
fn prop_packed_matches_dense_dequant() {
    for (seed, with_outliers) in [(201u64, false), (202, true)] {
        check("packed kernel vs dense", Config { cases: 24, seed }, move |rng| {
            let (_, qm) = random_quantized(rng, with_outliers);
            let deq = qm.dequantize();
            let packed = PackedLinear::from_quantized(&qm, None);
            let seq = 1 + rng.below_usize(4);
            let mut x = vec![0.0f32; seq * qm.cols];
            rng.fill_normal(&mut x, 1.0);
            let want = dense_forward(&deq, &x, seq);
            let mut got = vec![0.0f32; seq * qm.rows];
            let mut scratch = LinearScratch::new();
            packed.forward_into(&x, seq, &mut got, &mut scratch);
            assert_close(&got, &want, 1e-5);
        });
    }
}

/// With AWQ scales folded in, the packed kernel matches the scaled dense
/// reconstruction (`to_dense` semantics: dequantize, then divide columns).
#[test]
fn prop_packed_matches_dense_with_awq_scales() {
    check("packed kernel + awq", Config { cases: 24, seed: 203 }, |rng| {
        let (_, qm) = random_quantized(rng, true);
        let scales: Vec<f32> = (0..qm.cols).map(|_| 0.5 + 1.5 * rng.next_f32()).collect();
        let mut deq = qm.dequantize();
        for r in 0..deq.rows {
            let row = deq.row_mut(r);
            for (v, &s) in row.iter_mut().zip(&scales) {
                *v /= s;
            }
        }
        let packed = PackedLinear::from_quantized(&qm, Some(&scales));
        let seq = 1 + rng.below_usize(3);
        let mut x = vec![0.0f32; seq * qm.cols];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_forward(&deq, &x, seq);
        let mut got = vec![0.0f32; seq * qm.rows];
        let mut scratch = LinearScratch::new();
        packed.forward_into(&x, seq, &mut got, &mut scratch);
        assert_close(&got, &want, 1e-5);
    });
}

/// Built from a serialized container, the backend sees f16-rounded
/// codebooks — exactly what `unpack().dequantize()` reconstructs.
#[test]
fn prop_container_backend_matches_unpacked_dense() {
    check("container backend", Config { cases: 16, seed: 204 }, |rng| {
        let (_, qm) = random_quantized(rng, true);
        let (pm, _) = pack(&qm).unwrap();
        let packed = PackedLinear::from_container(&pm, None).unwrap();
        let deq = claq::quant::packed::unpack(&pm).unwrap().dequantize();
        let mut x = vec![0.0f32; qm.cols];
        rng.fill_normal(&mut x, 1.0);
        let want = dense_forward(&deq, &x, 1);
        let mut got = vec![0.0f32; qm.rows];
        let mut scratch = LinearScratch::new();
        packed.forward_into(&x, 1, &mut got, &mut scratch);
        assert_close(&got, &want, 1e-5);
    });
}

// ------------------------------------------------------------- f16 edges --

#[test]
fn f16_round_to_even_at_mantissa_boundary() {
    // 1.0 + 2^-11 is exactly halfway between 1.0 (0x3C00) and the next
    // representable (0x3C01): ties go to the even code.
    assert_eq!(f32_to_f16_bits(1.0 + (-11f32).exp2()), 0x3C00);
    // 1.0 + 3·2^-11 is halfway between 0x3C01 and 0x3C02: even is 0x3C02.
    assert_eq!(f32_to_f16_bits(1.0 + 3.0 * (-11f32).exp2()), 0x3C02);
}

#[test]
fn f16_subnormal_edges() {
    let min_sub = (-24f32).exp2(); // smallest positive f16 subnormal
    assert_eq!(f32_to_f16_bits(min_sub), 0x0001);
    assert_eq!(f16_bits_to_f32(0x0001), min_sub);
    // half the smallest subnormal: tie between 0 and 0x0001 → even (0)
    assert_eq!(f32_to_f16_bits(min_sub / 2.0), 0x0000);
    // 1.5× the smallest subnormal: tie between 0x0001 and 0x0002 → 0x0002
    assert_eq!(f32_to_f16_bits(1.5 * min_sub), 0x0002);
    // largest subnormal and smallest normal straddle 2^-14
    assert_eq!(f32_to_f16_bits(1023.0 * min_sub), 0x03FF);
    assert_eq!(f16_bits_to_f32(0x03FF), 1023.0 * min_sub);
    assert_eq!(f32_to_f16_bits((-14f32).exp2()), 0x0400);
    // below half the smallest subnormal flushes to signed zero
    assert_eq!(f32_to_f16_bits(min_sub / 4.0), 0x0000);
    assert_eq!(f32_to_f16_bits(-min_sub / 4.0), 0x8000);
}

#[test]
fn f16_inf_nan_and_overflow() {
    assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
    assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
    assert!(f16_bits_to_f32(0x7C01).is_nan());
    let nan = f32_to_f16_bits(f32::NAN);
    assert_eq!(nan & 0x7C00, 0x7C00);
    assert_ne!(nan & 0x03FF, 0, "NaN must keep a nonzero mantissa");
    let neg_nan = f32_to_f16_bits(f32::from_bits(0xFFC0_0000));
    assert_eq!(neg_nan & 0x8000, 0x8000, "NaN sign preserved");
    assert_ne!(neg_nan & 0x03FF, 0);
    // max finite f16 survives; first value past the rounding boundary
    // (65520 = midpoint to 65536) overflows to inf
    assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
    assert_eq!(f16_bits_to_f32(0x7BFF), 65504.0);
    assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
    assert_eq!(f32_to_f16_bits(-65520.0), 0xFC00);
}

#[test]
fn f16_round_trip_randoms_within_half_ulp() {
    check("f16 round trip", Config { cases: 256, seed: 205 }, |rng| {
        let x = rng.normal_f32() * 100.0;
        let y = f16_bits_to_f32(f32_to_f16_bits(x));
        if x.abs() > 1e-3 {
            assert!(((x - y) / x).abs() <= 1.0 / 2048.0, "{x} -> {y}");
        }
    });
}
