//! Property suite for the tiled LUT-blocked gather kernel: sweeps bit
//! widths {2,3,4} × f32/f16 (container) codebooks × outlier reservations ×
//! ragged shapes and checks the renegotiated accumulation contract —
//! tiled vs scalar agree to tolerance (different fixed combine trees),
//! while everything the serving stack's bit-identity properties rest on
//! (serial vs sharded vs batched, and repeated runs) stays exactly
//! bit-identical under the tiled kernel.

use claq::model::exec::{decode_step, prefill, ExecState, KvCache};
use claq::model::linear::{KernelKind, LinearOp, LinearScratch, PackedLinear};
use claq::model::quantized::QuantizedModel;
use claq::model::{Model, TransformerConfig};
use claq::quant::config::Method;
use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan, QuantizedMatrix};
use claq::quant::packed::pack;
use claq::tensor::Matrix;
use claq::util::proptest::{check, gen_column, Config};
use claq::util::rng::Rng;

/// Random ragged-shaped quantized matrix: bits 2..=4 per column, optional
/// outlier reservations, rows/cols chosen to land on and off the COL_TILE
/// and byte boundaries the bulk unpacker special-cases.
fn random_quantized(rng: &mut Rng, with_outliers: bool) -> QuantizedMatrix {
    let rows = 3 + rng.below_usize(62); // 3..=64: crosses u64-window tails
    let cols = 1 + rng.below_usize(23); // 1..=23: ragged vs COL_TILE=4
    let mut w = Matrix::zeros(rows, cols);
    for c in 0..cols {
        let col = gen_column(rng, rows, 0.05);
        w.set_col(c, &col);
    }
    let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
    for c in 0..cols {
        plan.bits[c] = 2 + rng.below_usize(3) as u8; // 2..=4 bits
    }
    if with_outliers {
        plan.reserve = (0..cols).map(|_| rng.below_usize(3)).collect();
    }
    quantize_matrix(&w, None, &plan)
}

fn forward(lin: &PackedLinear, x: &[f32], seq: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * lin.out_features()];
    let mut scratch = LinearScratch::new();
    lin.forward_into(x, seq, &mut out, &mut scratch);
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    for (a, b) in got.iter().zip(want) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "tiled {a} vs scalar {b} (tol {tol})");
    }
}

/// Tiled == scalar to tolerance for f32 codebooks, with and without
/// outlier columns, over random ragged shapes and batch sizes.
#[test]
fn prop_tiled_matches_scalar_f32_codebooks() {
    for (seed, with_outliers) in [(601u64, false), (602, true)] {
        check("tiled vs scalar f32", Config { cases: 32, seed }, move |rng| {
            let qm = random_quantized(rng, with_outliers);
            let scalar = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Scalar);
            let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);
            let seq = 1 + rng.below_usize(5);
            let mut x = vec![0.0f32; seq * qm.cols];
            rng.fill_normal(&mut x, 1.0);
            assert_close(&forward(&tiled, &x, seq), &forward(&scalar, &x, seq), 1e-5);
        });
    }
}

/// Same property through the serialized container, so the codebooks the
/// kernels gather from are f16-rounded — and with AWQ scales folded in.
#[test]
fn prop_tiled_matches_scalar_f16_container_and_awq() {
    check("tiled vs scalar f16+awq", Config { cases: 24, seed: 603 }, |rng| {
        let qm = random_quantized(rng, true);
        let scales: Vec<f32> = (0..qm.cols).map(|_| 0.5 + 1.5 * rng.next_f32()).collect();
        let (pm, _) = pack(&qm).unwrap();
        let scalar = PackedLinear::from_container(&pm, Some(&scales))
            .unwrap()
            .with_kernel(KernelKind::Scalar);
        let tiled = PackedLinear::from_container(&pm, Some(&scales))
            .unwrap()
            .with_kernel(KernelKind::Tiled);
        let seq = 1 + rng.below_usize(4);
        let mut x = vec![0.0f32; seq * qm.cols];
        rng.fill_normal(&mut x, 1.0);
        assert_close(&forward(&tiled, &x, seq), &forward(&scalar, &x, seq), 1e-5);
    });
}

/// The tiled kernel's bit-identity contract: batched output equals
/// token-at-a-time output EXACTLY (`assert_eq!`), including shapes large
/// enough to cross the parallel row-sharding threshold — the accumulation
/// order for each output element is a function of `cols` alone, never of
/// seq, shard count, or which path ran.
#[test]
fn prop_tiled_batched_and_sharded_bit_identical_to_serial() {
    check("tiled bit identity", Config { cases: 12, seed: 604 }, |rng| {
        // big enough that seq·rows·cols crosses PAR_MIN_MACS on most draws
        let rows = 96 + rng.below_usize(96);
        let cols = 32 + rng.below_usize(64);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.1);
        let mut plan = MatrixPlan::uniform(cols, 3, CentroidRule::KMeans, false);
        plan.reserve = vec![1; cols];
        let qm = quantize_matrix(&w, None, &plan);
        let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);

        let seq = 2 + rng.below_usize(7);
        let mut x = vec![0.0f32; seq * cols];
        rng.fill_normal(&mut x, 1.0);

        // token-at-a-time reference (serial path: one row, small MACs)
        let mut want = vec![0.0f32; seq * rows];
        let mut scratch = LinearScratch::new();
        for t in 0..seq {
            let mut row_out = vec![0.0f32; rows];
            tiled.forward_into(&x[t * cols..(t + 1) * cols], 1, &mut row_out, &mut scratch);
            want[t * rows..(t + 1) * rows].copy_from_slice(&row_out);
        }

        let got = forward(&tiled, &x, seq);
        assert_eq!(got, want, "tiled batched/sharded output diverged from serial");

        // and the whole thing is deterministic run over run
        assert_eq!(forward(&tiled, &x, seq), got);
    });
}

/// End to end: a full transformer built with `to_exec_kernel` produces
/// logits under the tiled kernel that (a) match the scalar kernel to
/// tolerance and (b) are bit-identical between batched decode and
/// one-cache-at-a-time decode.
#[test]
fn exec_model_tiled_vs_scalar_and_batch_invariance() {
    let cfg = TransformerConfig::tiny_l();
    let model = Model::random(cfg, &mut Rng::new(42));
    let qm = QuantizedModel::quantize_uncalibrated(&model, &Method::fusion_2_12());
    let scalar = qm.to_exec_kernel(KernelKind::Scalar);
    let tiled = qm.to_exec_kernel(KernelKind::Tiled);
    let prompt: Vec<u16> = (0..12u16).map(|i| (i * 5) % cfg.vocab as u16).collect();

    // (a) tolerance agreement of full-model logits
    let mut st_s = ExecState::new(cfg);
    let mut st_t = ExecState::new(cfg);
    let mut cache_s = KvCache::new(&cfg);
    let mut cache_t = KvCache::new(&cfg);
    let logits_s = prefill(&scalar, &mut cache_s, &prompt, &mut st_s);
    let logits_t = prefill(&tiled, &mut cache_t, &prompt, &mut st_t);
    for (a, b) in logits_t.data.iter().zip(&logits_s.data) {
        assert!(a.is_finite() && (a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }

    // (b) batched decode == per-cache decode, bit-identical, under tiled
    let batch = 3usize;
    let toks: Vec<u16> = (0..batch as u16).map(|i| (i * 11 + 1) % cfg.vocab as u16).collect();
    let mut batched: Vec<KvCache> = (0..batch)
        .map(|_| {
            let mut c = KvCache::new(&cfg);
            let _ = prefill(&tiled, &mut c, &prompt, &mut st_t);
            c
        })
        .collect();
    let mut alone: Vec<KvCache> = (0..batch)
        .map(|_| {
            let mut c = KvCache::new(&cfg);
            let _ = prefill(&tiled, &mut c, &prompt, &mut st_t);
            c
        })
        .collect();
    let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
    let together = decode_step(&tiled, &mut refs, &toks, &mut st_t);
    for (i, c) in alone.iter_mut().enumerate() {
        let one = decode_step(&tiled, &mut [c], &toks[i..i + 1], &mut st_t);
        assert_eq!(
            together.row(i),
            one.row(0),
            "tiled decode not batch-invariant at slot {i}"
        );
    }
}
