//! Mixed-precision property suite: the end-to-end contracts ISSUE 10's
//! tentpole rests on, pinned from outside the crate.
//!
//! 1. The typed method-spec grammar round-trips: `parse(display(spec))`
//!    yields an equal [`Method`] for every spec the parser can produce,
//!    and malformed specs fail with the grammar in the error.
//! 2. Mixed-bit packed kernels: tiled agrees with scalar (and with the
//!    dense dequant) to 1e-5 over adversarial per-column bit patterns —
//!    run boundaries mid-tile, ragged tails, all-lo/all-hi degenerate
//!    plans.
//! 3. Bit-identity: sharded and batched mixed-bit forwards equal the
//!    row-at-a-time serial forward bit-for-bit under both kernels.
//! 4. CLAQPK01 containers account mixed-bit planes byte-exactly per
//!    column, and corrupt per-column bit tags are rejected.
//! 5. Adaptive precision hits its bit budget: container bits/param within
//!    0.01 of the AP target at realistic widths.
//! 6. A mixed-bit model packed via a parsed spec serves from a cold-loaded
//!    checkpoint bit-identically to the in-memory deployed path across
//!    prefill, batch-1 greedy decode, and batch-3 decode.

use claq::coordinator::pipeline::{quantize_model, PipelineOpts};
use claq::data::calibration::{sample_segments, CalibConfig};
use claq::data::corpus::{generate, CorpusKind, VOCAB};
use claq::model::checkpoint::Checkpoint;
use claq::model::exec::{argmax, decode_step, prefill, ExecModel, ExecState, KvCache};
use claq::model::linear::{KernelKind, LinearOp, LinearScratch, PackedLinear};
use claq::model::{Model, TransformerConfig};
use claq::quant::config::{Method, MethodSpec};
use claq::quant::gptq::{quantize_matrix, CentroidRule, MatrixPlan, QuantizedMatrix};
use claq::quant::packed::{pack, unpack};
use claq::tensor::Matrix;
use claq::util::rng::Rng;

// ------------------------------------------------------------ helpers ----

fn sample_mixed(
    seed: u64,
    rows: usize,
    cols: usize,
    reserve: usize,
    bit_of: impl Fn(usize) -> u8,
) -> (Matrix, QuantizedMatrix) {
    let mut rng = Rng::new(seed);
    let mut w = Matrix::zeros(rows, cols);
    rng.fill_normal(&mut w.data, 0.1);
    let mut plan = MatrixPlan::uniform(cols, 2, CentroidRule::KMeans, false);
    for (c, b) in plan.bits.iter_mut().enumerate() {
        *b = bit_of(c);
    }
    plan.reserve = vec![reserve; cols];
    let qm = quantize_matrix(&w, None, &plan);
    (w, qm)
}

fn forward(linear: &PackedLinear, x: &[f32], seq: usize, rows: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * rows];
    let mut scratch = LinearScratch::new();
    linear.forward_into(x, seq, &mut out, &mut scratch);
    out
}

fn assert_close(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: shape");
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()), "{ctx}: {x} vs {y}");
    }
}

// ------------------------------------------------------ 1. MethodSpec ----

/// Every family the grammar can express, including the degenerate corners.
/// `parse → display → parse` must land on an equal `Method`, and the
/// display must be stable (`display(parse(display(s))) == display(parse(s))`).
#[test]
fn method_spec_parse_display_round_trips() {
    let specs = [
        "fp16",
        "rtn:4",
        "gptq:3",
        "awq:4",
        "claq:2",
        "claq:8",
        "claq-ap:2+4@2.05",
        "claq-ap:3+4@3.5",
        "claq-or:2+0.14",
        "claq-or-fixed:3+0.07",
        "claq-vq:d4b2",
        "claq-vq:d1b2",
        "fusion-2.12",
        "fusion-2.24",
        "fusion-3.12",
        "fusion-3.23",
        "fusion:2+4@2.3+0.1",
    ];
    for s in specs {
        let spec: MethodSpec = s.parse().unwrap_or_else(|e| panic!("'{s}' failed: {e}"));
        let shown = spec.to_string();
        let again: MethodSpec = shown.parse().unwrap_or_else(|e| panic!("'{shown}' failed: {e}"));
        assert_eq!(spec, again, "'{s}' -> '{shown}' did not round-trip");
        assert_eq!(shown, again.to_string(), "'{s}': display not stable");
    }

    // parsing is case-insensitive and whitespace-tolerant
    let upper: MethodSpec = " CLAQ-AP:2+4@2.05 ".parse().unwrap();
    assert_eq!(upper, "claq-ap:2+4@2.05".parse().unwrap());

    // the historical alias spells the same preset
    let alias: MethodSpec = "claq-fusion-2.12".parse().unwrap();
    assert_eq!(alias.method(), &Method::fusion_2_12());
    assert_eq!(alias.to_string(), "fusion-2.12");

    // a generic fusion spec equal to a preset canonicalizes to the sugar
    let generic: MethodSpec = "fusion:2+4@2.05+0.07".parse().unwrap();
    assert_eq!(generic.to_string(), "fusion-2.12");
}

#[test]
fn method_spec_rejects_malformed_with_grammar() {
    let bad = [
        "claq",             // missing ':B'
        "claq:0",           // bits below 1
        "claq:9",           // bits above the container's 8-bit planes
        "claq-ap:4+2@3",    // LO >= HI
        "claq-ap:2+4@5.0",  // target outside [lo, hi]
        "claq-ap:2+4",      // missing '@TARGET'
        "claq-or:2",        // missing '+E'
        "claq-or:2+17",     // budget out of range
        "claq-vq:4b2",      // missing the 'd' prefix
        "claq-vq:d0b2",     // zero group dim
        "fusion-9.99",      // unknown Appendix F preset
        "fusion:2+4@2.05",  // missing OR budget
        "quantize-harder",  // unknown family
        "",
    ];
    for s in bad {
        let err = match s.parse::<MethodSpec>() {
            Ok(spec) => panic!("'{s}' should not parse, got {spec:?}"),
            Err(e) => e,
        };
        assert!(err.contains("grammar"), "'{s}': error lacks the grammar hint: {err}");
    }
}

// --------------------------------------------- 2. tiled vs scalar 1e-5 ----

/// Adversarial per-column bit patterns: every tile either sits inside one
/// equal-bit run (fused decode) or straddles a boundary (per-lane
/// fallback), plus ragged tails and degenerate all-lo/all-hi plans. Both
/// kernels must match the dense dequant to 1e-5 on all of them.
#[test]
fn mixed_bit_plans_tiled_matches_scalar_and_dense() {
    type Pattern = (&'static str, usize, Box<dyn Fn(usize) -> u8>);
    let patterns: Vec<Pattern> = vec![
        ("alternating", 17, Box::new(|c| if c % 2 == 0 { 2 } else { 4 })),
        ("runs-of-3", 23, Box::new(|c| [2u8, 3, 4][(c / 3) % 3])),
        ("all-lo", 16, Box::new(|_| 2)),
        ("all-hi", 14, Box::new(|_| 8)),
        ("one-wide-col", 12, Box::new(|c| if c == 5 { 8 } else { 2 })),
        ("random-ish", 31, Box::new(|c| 2 + ((c * 7 + 3) % 4) as u8)),
    ];
    for (name, cols, bit_of) in patterns {
        let (_, qm) = sample_mixed(7 + cols as u64, 29, cols, 1, bit_of);
        let deq = qm.dequantize();
        let mut rng = Rng::new(100 + cols as u64);
        let seq = 3;
        let mut x = vec![0.0f32; seq * cols];
        rng.fill_normal(&mut x, 1.0);

        let mut want = vec![0.0f32; seq * 29];
        let mut scratch = LinearScratch::new();
        deq.forward_into(&x, seq, &mut want, &mut scratch);

        let scalar = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Scalar);
        let tiled = PackedLinear::from_quantized(&qm, None).with_kernel(KernelKind::Tiled);
        let a = forward(&scalar, &x, seq, 29);
        let b = forward(&tiled, &x, seq, 29);
        assert_close(&a, &want, &format!("{name}: scalar vs dense"));
        assert_close(&b, &want, &format!("{name}: tiled vs dense"));
        assert_close(&b, &a, &format!("{name}: tiled vs scalar"));
    }
}

// ------------------------------------------------------ 3. bit-identity ----

/// Serial (row-at-a-time), sharded (seq over the parallel threshold), and
/// batched (several rows in one call) mixed-bit forwards are bit-identical
/// under both kernels: the accumulation schedule is a function of `cols`
/// alone, never of the run structure or the batch shape.
#[test]
fn mixed_bit_serial_sharded_batched_bit_identical() {
    let (rows, cols) = (160, 96);
    let (_, qm) = sample_mixed(51, rows, cols, 1, |c| match c % 11 {
        0..=3 => 2,
        4..=8 => 4,
        _ => 8,
    });
    for kernel in [KernelKind::Scalar, KernelKind::Tiled] {
        let packed = PackedLinear::from_quantized(&qm, None).with_kernel(kernel);
        let mut rng = Rng::new(52);
        let seq = 8; // 8 × 160 × 96 MACs — over the parallel threshold
        let mut x = vec![0.0f32; seq * cols];
        rng.fill_normal(&mut x, 1.0);

        // serial reference: one token per call
        let mut serial = vec![0.0f32; seq * rows];
        let mut scratch = LinearScratch::new();
        for t in 0..seq {
            let row = &x[t * cols..(t + 1) * cols];
            packed.forward_into(row, 1, &mut serial[t * rows..(t + 1) * rows], &mut scratch);
        }

        // sharded: all tokens at once
        let sharded = forward(&packed, &x, seq, rows);
        assert_eq!(sharded, serial, "{kernel:?}: sharded != serial");

        // batched: 3 + 5 split must hit the same bits as 8-at-once
        let mut batched = vec![0.0f32; seq * rows];
        packed.forward_into(&x[..3 * cols], 3, &mut batched[..3 * rows], &mut scratch);
        packed.forward_into(&x[3 * cols..], 5, &mut batched[3 * rows..], &mut scratch);
        assert_eq!(batched, serial, "{kernel:?}: batched != serial");
    }
}

// --------------------------------------- 4. container byte accounting ----

/// CLAQPK01 stores mixed-bit planes with exact per-column accounting:
/// 20 header bytes, then per column 1 bits byte + 2·2^bits f16 centroids +
/// ceil(rows·bits/8) plane bytes, then 12 bytes per outlier. The size
/// report partitions the same total, unpack→re-pack is byte-stable, and a
/// zeroed per-column bit tag is rejected.
#[test]
fn mixed_bit_container_byte_accounting_exact() {
    let (rows, cols) = (33, 14);
    let bits: [u8; 14] = [2, 2, 2, 2, 2, 2, 4, 4, 4, 3, 3, 3, 3, 8];
    let (_, qm) = sample_mixed(61, rows, cols, 2, |c| bits[c]);
    let (pm, report) = pack(&qm).unwrap();

    let header = 8 + 4 + 4 + 4;
    let per_column: usize =
        bits.iter().map(|&b| 1 + 2 * (1usize << b) + (rows * b as usize).div_ceil(8)).sum();
    let outliers = 12 * qm.outliers.len();
    assert_eq!(qm.outliers.len(), 2 * cols, "reserve=2 on every column");
    assert_eq!(pm.bytes.len(), header + per_column + outliers, "container length");
    assert_eq!(report.header_bytes, header);
    assert_eq!(report.outlier_bytes, outliers);
    assert_eq!(
        report.index_bytes + report.codebook_bytes,
        per_column,
        "per-column bytes split into index planes + (bits byte, codebook)"
    );
    assert_eq!(report.container_bytes(), pm.bytes.len(), "report covers every byte");

    // per-column bits survive the round trip, and re-packing is byte-stable
    let back = unpack(&pm).unwrap();
    let got: Vec<u8> = back.columns().iter().map(|c| c.bits).collect();
    assert_eq!(got, bits.to_vec());
    let (pm2, _) = pack(&back).unwrap();
    assert_eq!(pm.bytes, pm2.bytes);

    // a zeroed bit tag desyncs the stream — the reader must refuse it
    let mut bad = pm.bytes.clone();
    bad[header] = 0;
    assert!(
        unpack(&claq::quant::packed::PackedMatrix { bytes: bad }).is_err(),
        "zero bit width accepted"
    );
}

// --------------------------------------------------- 5. AP bit budgets ----

/// Adaptive precision lands its budget: at 128 columns the promote
/// granularity is (hi−lo)/(2·cols) ≈ 0.008, so the packed container's
/// paper-accounted bits/param must sit within 0.01 of the AP target.
#[test]
fn ap_container_bits_per_param_within_a_hundredth() {
    for target in [2.05, 2.5, 3.0] {
        let spec: MethodSpec = format!("claq-ap:2+4@{target}").parse().unwrap();
        let mut rng = Rng::new(71);
        let mut w = Matrix::zeros(64, 128);
        rng.fill_normal(&mut w.data, 0.1);
        let plan = spec.method().plan_for(&w, None).unwrap();
        let qm = quantize_matrix(&w, None, &plan);
        let (_, report) = pack(&qm).unwrap();
        let got = report.paper_equivalent_bits;
        assert!(
            (got - target).abs() <= 0.01,
            "claq-ap:2+4@{target}: achieved {got} bits/param, off by more than 0.01"
        );
    }
}

// ----------------------------------------- 6. pack → serve end-to-end ----

fn assert_bits_equal(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: shape");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: logit {i}: {x} vs {y}");
    }
}

/// A mixed-bit model quantized via a *parsed spec* (pure AP — every
/// projection carries mixed per-column bits, no outlier reservation to
/// mask plane bugs) round-trips through a CLAQMD01 checkpoint and serves
/// bit-identically to the in-memory deployed path: prefill, batch-1 greedy
/// decode, and batch-3 decode all produce the same logits, hence the same
/// tokens.
#[test]
fn mixed_bit_checkpoint_serves_bit_identically() {
    let cfg = TransformerConfig {
        vocab: VOCAB,
        d_model: 24,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_theta: 10000.0,
        eps: 1e-5,
    };
    let model = Model::random(cfg, &mut Rng::new(81));
    let stream = generate(CorpusKind::SynthC4, 4000, 1);
    let calib = sample_segments(&stream, &CalibConfig { n_segments: 6, seq_len: 32, seed: 8 });
    let spec: MethodSpec = "claq-ap:2+4@2.5".parse().unwrap();
    let (qm, _) = quantize_model(&model, spec.method(), &calib, &PipelineOpts::default());

    let path = claq::util::tmp::unique_path("mixed_bits_e2e");
    qm.save(&path).unwrap();
    let cold = ExecModel::from_checkpoint(Checkpoint::load(&path).unwrap()).unwrap();
    assert_eq!(cold.backend, "packed");
    let deployed = qm.to_exec_deployed().unwrap();

    let mut st = ExecState::new(cfg);
    let toks: Vec<u16> = (0..16u16).map(|i| (i * 37) % VOCAB as u16).collect();
    let mut ca = KvCache::new(&cfg);
    let mut cb = KvCache::new(&cfg);
    let la = prefill(&cold, &mut ca, &toks, &mut st);
    let lb = prefill(&deployed, &mut cb, &toks, &mut st);
    assert_bits_equal(&la.data, &lb.data, "prefill");

    let mut ta = argmax(la.row(toks.len() - 1));
    let mut tb = argmax(lb.row(toks.len() - 1));
    for step in 0..6 {
        assert_eq!(ta, tb, "greedy token diverged at step {step}");
        let la = decode_step(&cold, &mut [&mut ca], &[ta], &mut st);
        let lb = decode_step(&deployed, &mut [&mut cb], &[tb], &mut st);
        assert_bits_equal(&la.data, &lb.data, &format!("decode step {step}"));
        ta = argmax(la.row(0));
        tb = argmax(lb.row(0));
    }

    // batch-3 decode at mixed depths exercises the batched dispatch path
    let prompts: [&[u16]; 3] = [&[1, 2, 3], &[9, 8, 7, 6, 5], &[40, 0]];
    let mk = |m: &ExecModel, st: &mut ExecState| -> Vec<KvCache> {
        prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&cfg);
                let _ = prefill(m, &mut c, p, st);
                c
            })
            .collect()
    };
    let mut caches_a = mk(&cold, &mut st);
    let mut caches_b = mk(&deployed, &mut st);
    let next = [4u16, 11, 200];
    let mut refs_a: Vec<&mut KvCache> = caches_a.iter_mut().collect();
    let mut refs_b: Vec<&mut KvCache> = caches_b.iter_mut().collect();
    let la = decode_step(&cold, &mut refs_a, &next, &mut st);
    let lb = decode_step(&deployed, &mut refs_b, &next, &mut st);
    assert_bits_equal(&la.data, &lb.data, "batch-3 decode");

    let _ = std::fs::remove_file(&path);
}
